"""Unit tests for ADA_OPT (Algorithm 2) semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import AdaConfig, apply_update, init_opt_state, \
    opt_state_bytes


def _params():
    return {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([0.5])}


def _update():
    return {"w": jnp.array([0.1, -0.2, 0.3]), "b": jnp.array([-0.4])}


def test_sgd_step():
    cfg = AdaConfig(name="sgd", lr=0.5)
    p, s = apply_update(cfg, init_opt_state(cfg, _params()), _params(), _update())
    np.testing.assert_allclose(np.array(p["w"]),
                               np.array([1.0, -2.0, 3.0]) - 0.5 * np.array([0.1, -0.2, 0.3]),
                               rtol=1e-6)
    assert int(s["step"]) == 1


def test_amsgrad_matches_algorithm2():
    """First step of Alg. 2 closed form: m=(1-b1)u, v=(1-b2)u^2,
    vhat=max(0,v)=v, x -= k * m/(sqrt(vhat)+eps)."""
    cfg = AdaConfig(name="amsgrad", lr=0.1, beta1=0.9, beta2=0.99, eps=1e-8)
    u = _update()
    p, s = apply_update(cfg, init_opt_state(cfg, _params()), _params(), u)
    m = 0.1 * np.array([0.1, -0.2, 0.3])
    v = 0.01 * np.array([0.1, -0.2, 0.3]) ** 2
    want = np.array([1.0, -2.0, 3.0]) - 0.1 * m / (np.sqrt(v) + 1e-8)
    np.testing.assert_allclose(np.array(p["w"]), want, rtol=1e-5)
    np.testing.assert_allclose(np.array(s["vhat"]["w"]), v, rtol=1e-6)


def test_amsgrad_vhat_monotone():
    cfg = AdaConfig(name="amsgrad", lr=0.01)
    params = _params()
    state = init_opt_state(cfg, params)
    prev = None
    for t in range(5):
        u = jax.tree.map(lambda x: x * (0.5 ** t), _update())
        params, state = apply_update(cfg, state, params, u)
        vh = np.array(state["vhat"]["w"])
        if prev is not None:
            assert (vh >= prev - 1e-9).all()
        prev = vh


def test_adam_vs_amsgrad_divergence():
    """With shrinking updates, Adam's v decays but AMSGrad's vhat does not."""
    ca = AdaConfig(name="adam", lr=0.01)
    cm = AdaConfig(name="amsgrad", lr=0.01)
    pa, sa = _params(), init_opt_state(ca, _params())
    pm, sm = _params(), init_opt_state(cm, _params())
    for t in range(20):
        u = jax.tree.map(lambda x: x * (0.5 ** t), _update())
        pa, sa = apply_update(ca, sa, pa, u)
        pm, sm = apply_update(cm, sm, pm, u)
    assert float(sm["vhat"]["w"].max()) > float(sa["v"]["w"].max())


def test_adagrad_accumulates():
    cfg = AdaConfig(name="adagrad", lr=0.1)
    params, state = _params(), init_opt_state(AdaConfig(name="adagrad"), _params())
    for _ in range(3):
        params, state = apply_update(cfg, state, params, _update())
    np.testing.assert_allclose(np.array(state["v"]["w"]),
                               3 * np.array([0.1, -0.2, 0.3]) ** 2, rtol=1e-5)


def test_weight_decay():
    cfg = AdaConfig(name="sgd", lr=1.0, weight_decay=0.1)
    zero_u = jax.tree.map(jnp.zeros_like, _update())
    p, _ = apply_update(cfg, init_opt_state(cfg, _params()), _params(), zero_u)
    np.testing.assert_allclose(np.array(p["w"]),
                               0.9 * np.array([1.0, -2.0, 3.0]), rtol=1e-6)


def test_bf16_moments():
    cfg = AdaConfig(name="amsgrad", moment_dtype=jnp.bfloat16)
    state = init_opt_state(cfg, _params())
    assert state["m"]["w"].dtype == jnp.bfloat16
    p, s = apply_update(cfg, state, _params(), _update())
    assert s["v"]["w"].dtype == jnp.bfloat16
    assert p["w"].dtype == jnp.float32


def test_opt_state_bytes():
    params = {"w": jnp.zeros((10, 10))}
    assert opt_state_bytes(AdaConfig(name="amsgrad"), params) == 100 * 3 * 4
    assert opt_state_bytes(AdaConfig(name="sgd"), params) == 0


def test_lr_scale():
    cfg = AdaConfig(name="sgd", lr=1.0)
    p1, _ = apply_update(cfg, init_opt_state(cfg, _params()), _params(),
                         _update(), lr_scale=0.5)
    p2, _ = apply_update(AdaConfig(name="sgd", lr=0.5),
                         init_opt_state(cfg, _params()), _params(), _update())
    np.testing.assert_allclose(np.array(p1["w"]), np.array(p2["w"]), rtol=1e-6)
