"""Fault injection, payload sentinels, rollback supervisor (DESIGN.md §10).

Pins the ISSUE 7 contracts on the single-host scan driver (the mesh-path
twins live in tests/test_mesh_scan.py, which owns the 8-device harness):

  * a neutral fault policy (all rates 0) is BITWISE the hookless scan, on
    the sync, clipped, participation-masked and async-buffered paths;
  * a sentinel-guarded clean run matches the unguarded trajectory to
    float32 ulps with zero rejections (bitwise is impossible: the extra
    counter outputs alone shift XLA's fusion choices -- fed/robust.py);
  * a NaN-corrupted client round is BITWISE the same round with that
    client dropout-masked (both sides compile the same guarded program);
  * any scripted fault pattern leaves post-aggregation params finite under
    the sentinels, including all-drop rounds (empty-cohort carry-through)
    and majority-honest Byzantine scaling (norm-outlier rejection);
  * the supervisor escapes transient faults by rekeyed rollback from the
    last good (t, key) cursor, exhausts its retry budget on persistent
    faults, and stitches a finite full-length history.

Hypothesis property tests ride along under ``importorskip`` (the tier-1
container has no hypothesis; tools/check_skipped_files.py still sees this
module alive through the deterministic tests).
"""

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.safl import fedopt_round, init_safl
from repro.fed import (BYZANTINE, DROP, INF, NAN, OK, AsyncConfig,
                       FaultConfig, FaultTable, SentinelConfig,
                       UniformParticipation, init_async_state,
                       make_async_round)
from repro.fed.faults import _spec_from_codes
from repro.launch.driver import run_host_loop, run_scan
from repro.launch.supervisor import (SupervisorConfig, SupervisorError,
                                     chunk_is_bad, format_recovery_log,
                                     run_supervised)
from test_fed import (G, _LinearSampler, _linear_loss, _params0, _safl_setup,
                      _SK)

SENT = SentinelConfig(norm_mult=10.0)


def _finite(tree) -> bool:
    return all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(tree))


def _run(round_fn, fresh, *, rounds=8, chunk_size=4, **kw):
    p0, s0 = fresh()
    return run_scan(round_fn, _LinearSampler(), p0, s0, rounds=rounds,
                    key=jax.random.key(0), chunk_size=chunk_size, **kw)


def _eq(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _row(code, client=1):
    return tuple(code if c == client else OK for c in range(G))


# ---------------------------------------------------------------------------
# neutrality: disabled faults leave every trajectory bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("clip", [False, True], ids=["safl", "clipped"])
def test_neutral_faults_bitwise(clip):
    """All-zero fault rates == no faults hook at all, bit for bit: the
    neutral spec multiplies payloads by 1.0 and the mask by an all-ones
    arrival vector, and adds only the n_dropped counter output."""
    _, _, round_fn, fresh = _safl_setup(clip=clip)
    pA, sA, hA = _run(round_fn, fresh)
    pB, sB, hB = _run(round_fn, fresh, faults=FaultConfig(num_clients=G))
    _eq((pA, sA), (pB, sB))
    np.testing.assert_array_equal(hA["loss"], hB["loss"])
    assert hB["n_dropped"].sum() == 0


def test_neutral_faults_bitwise_with_participation():
    """Fault arrivals fold multiplicatively into the cohort mask, so a
    neutral policy leaves a participation-masked run untouched too."""
    _, _, round_fn, fresh = _safl_setup()
    part = UniformParticipation(num_clients=G, frac=0.5, seed=3)
    pA, _, hA = _run(round_fn, fresh, participation=part)
    pB, _, hB = _run(round_fn, fresh, participation=part,
                     faults=FaultConfig(num_clients=G))
    _eq(pA, pB)
    np.testing.assert_array_equal(hA["loss"], hB["loss"])


def test_neutral_faults_bitwise_async():
    cfg, plan, _, _ = _safl_setup()
    acfg = AsyncConfig(max_delay=2, delay="stagger")
    arf = make_async_round(cfg, _linear_loss, acfg, plan)
    fresh = lambda: (_params0(), init_async_state(cfg, acfg, _params0(),
                                                  plan, G))
    pA, sA, hA = _run(arf, fresh, buffer=True)
    pB, sB, hB = _run(arf, fresh, buffer=True,
                      faults=FaultConfig(num_clients=G))
    _eq((pA, sA), (pB, sB))
    np.testing.assert_array_equal(hA["loss"], hB["loss"])


def test_sentinel_clean_run_matches_unguarded():
    """Sentinels on a clean run: zero rejections, no divergence flags, and
    a trajectory equal to the unguarded one to float32 ulps (NOT bitwise --
    the extra metric outputs alone change XLA fusion, see fed/robust.py)."""
    _, _, round_fn, fresh = _safl_setup()
    pA, _, hA = _run(round_fn, fresh)
    rf = functools.partial(round_fn, sentinel=SENT)
    pB, _, hB = _run(rf, fresh, faults=FaultConfig(num_clients=G))
    assert hB["n_rejected"].sum() == 0
    assert hB["diverged"].sum() == 0
    np.testing.assert_allclose(np.asarray(pA["W"]), np.asarray(pB["W"]),
                               rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(hA["loss"], hB["loss"], rtol=2e-5)


def test_fault_stream_is_chunk_invariant():
    """Fault draws are pure in the absolute round index, so chunk splits
    and the host-loop reference see the identical fault pattern."""
    _, _, round_fn, fresh = _safl_setup()
    faults = FaultConfig(num_clients=G, drop_rate=0.3, seed=5)
    pA, _, hA = _run(round_fn, fresh, chunk_size=8, faults=faults)
    pB, _, hB = _run(round_fn, fresh, chunk_size=3, faults=faults)
    _eq(pA, pB)
    np.testing.assert_array_equal(hA["n_dropped"], hB["n_dropped"])
    p0, s0 = fresh()
    pC, _, hC = run_host_loop(round_fn, _LinearSampler(), p0, s0, rounds=8,
                              key=jax.random.key(0), faults=faults)
    _eq(pA, pC)
    np.testing.assert_array_equal(hA["n_dropped"], hC["n_dropped"])


# ---------------------------------------------------------------------------
# guarded fault semantics
# ---------------------------------------------------------------------------

def test_nan_equals_drop_bitwise():
    """A NaN-corrupted client round == the same round with that client
    dropout-masked, bit for bit, on params/opt/loss.  (The counters differ
    by design: one increments n_rejected, the other n_dropped.)"""
    _, _, round_fn, fresh = _safl_setup()
    rf = functools.partial(round_fn, sentinel=SENT)
    pA, sA, hA = _run(rf, fresh, faults=FaultTable(codes=(_row(NAN),) * 3))
    pB, sB, hB = _run(rf, fresh, faults=FaultTable(codes=(_row(DROP),) * 3))
    _eq((pA, sA), (pB, sB))
    np.testing.assert_array_equal(hA["loss"], hB["loss"])
    assert hA["n_rejected"].sum() == 3 and hA["n_dropped"].sum() == 0
    assert hB["n_dropped"].sum() == 3 and hB["n_rejected"].sum() == 0


def test_inf_equals_drop_bitwise_async():
    """Same property through the async buffer, with Inf corruption: the
    ring never stores a poisoned row (guarded BEFORE push), so the whole
    downstream trajectory matches the dropout-masked one."""
    cfg, plan, _, _ = _safl_setup()
    acfg = AsyncConfig(max_delay=2, delay="stagger")
    arf = functools.partial(make_async_round(cfg, _linear_loss, acfg, plan),
                            sentinel=SENT)
    fresh = lambda: (_params0(), init_async_state(cfg, acfg, _params0(),
                                                  plan, G))
    pA, sA, hA = _run(arf, fresh, buffer=True,
                      faults=FaultTable(codes=(_row(INF),) * 3))
    pB, sB, hB = _run(arf, fresh, buffer=True,
                      faults=FaultTable(codes=(_row(DROP),) * 3))
    # ring contents may differ where weights are 0 (zeroed vs honest row);
    # everything that feeds the trajectory must match exactly
    _eq((pA, sA["opt"]), (pB, sB["opt"]))
    np.testing.assert_array_equal(hA["loss"], hB["loss"])
    assert np.isfinite(np.asarray(sA["buf"])).all()
    assert _finite(pA)


def test_unguarded_nan_poisons_guarded_stays_finite():
    _, _, round_fn, fresh = _safl_setup()
    faults = FaultTable(codes=(_row(NAN),) * 3)
    pA, _, _ = _run(round_fn, fresh, faults=faults)
    assert not _finite(pA)
    rf = functools.partial(round_fn, sentinel=SENT)
    pB, _, hB = _run(rf, fresh, faults=faults)
    assert _finite(pB)
    assert np.isfinite(hB["loss"]).all()


def test_byzantine_rejected_by_norm_sentinel():
    """A 1e4-scaled payload is finite (passes the finite-check) but its
    sketch norm is ~1e8x the cohort median -- the norm rule rejects it and
    the run matches the drop-masked twin bitwise."""
    _, _, round_fn, fresh = _safl_setup()
    rf = functools.partial(round_fn, sentinel=SENT)
    byz = FaultTable(codes=(_row(BYZANTINE),) * 3, byzantine_scale=1e4)
    pA, sA, hA = _run(rf, fresh, faults=byz)
    pB, sB, hB = _run(rf, fresh, faults=FaultTable(codes=(_row(DROP),) * 3))
    _eq((pA, sA), (pB, sB))
    assert hA["n_rejected"].sum() == 3


def test_all_drop_round_carries_server_through():
    """An all-drop round under sentinels is a true no-op: params AND opt
    state carry through unchanged (an adaptive server applying a zero
    pseudo-gradient would still decay its moments)."""
    _, _, round_fn, fresh = _safl_setup()
    rf = functools.partial(round_fn, sentinel=SENT)
    all_drop = FaultTable(codes=((DROP,) * G,))
    sampler = _LinearSampler()
    p0, s0 = fresh()
    p1, s1, h1 = run_scan(rf, sampler, p0, s0, rounds=1,
                          key=jax.random.key(0),
                          faults=all_drop)
    p0, s0 = fresh()
    _eq((p1, s1), (p0, s0))
    assert h1["n_dropped"].sum() == G
    # ...and the run continues normally afterwards (rounds past the table
    # are fault-free)
    p2, s2, h2 = _run(rf, fresh, faults=all_drop)
    assert _finite(p2) and np.isfinite(h2["loss"]).all()


def test_fedopt_rejects_fault_kwargs():
    """The FedOPT baseline has no sketch payload for sketch-space faults
    or sentinels to act on -- both kwargs must fail loudly, not silently
    no-op."""
    cfg, _, _, _ = _safl_setup()
    sampler = _LinearSampler()
    st = sampler.init_state()
    _, batch = sampler.sample(st, jnp.asarray(0))
    p0 = _params0()
    s0 = init_safl(cfg, p0)
    with pytest.raises(ValueError, match="sketch"):
        fedopt_round(cfg, _linear_loss, p0, s0, batch, jax.random.key(1),
                     fault_spec=_spec_from_codes(jnp.zeros(G, jnp.int32),
                                                 1e3))
    with pytest.raises(ValueError, match="sketch"):
        fedopt_round(cfg, _linear_loss, p0, s0, batch, jax.random.key(1),
                     sentinel=SENT)


def test_fault_config_validation():
    with pytest.raises(AssertionError):
        FaultConfig(num_clients=G, drop_rate=0.6, nan_rate=0.6)
    with pytest.raises(AssertionError):
        FaultTable(codes=((OK, DROP), (OK,)))
    with pytest.raises(AssertionError):
        FaultTable(codes=((7, OK),))


# ---------------------------------------------------------------------------
# supervisor: rollback, rekey, bounded retries
# ---------------------------------------------------------------------------

class _TransientFaults:
    """Scripted faults that fire ONLY under a specific run key: the
    deterministic stand-in for a transient fault -- any rekeyed retry is
    clean by construction, so the tests exercise the rollback mechanism
    itself rather than a probability of escape."""

    def __init__(self, key0, codes_row, rounds=(4, 6), scale=1e3):
        self.kd0 = np.asarray(jax.random.key_data(key0))
        self.codes_row = jnp.asarray(codes_row, jnp.int32)
        self.lo, self.hi = rounds
        self.scale = scale

    def spec(self, t, base_key):
        same = jnp.all(jax.random.key_data(base_key) == self.kd0)
        hit = same & (t >= self.lo) & (t < self.hi)
        codes = jnp.where(hit, self.codes_row, OK)
        return _spec_from_codes(codes, self.scale)


def _launcher(round_fn, faults, rounds=8, chunk_size=2):
    sampler = _LinearSampler()

    def launch(p, s, *, key, start_round, on_chunk):
        return run_scan(round_fn, sampler, p, s, rounds=rounds, key=key,
                        chunk_size=chunk_size, start_round=start_round,
                        on_chunk=on_chunk, faults=faults)
    return launch


def test_supervisor_escapes_transient_fault(tmp_path):
    """Unguarded transient NaN payloads poison the run; the supervisor
    detects the non-finite chunk, rolls back to the last good cursor,
    rekeys, and completes with finite params and a full stitched history."""
    _, _, round_fn, fresh = _safl_setup()
    key = jax.random.key(0)
    faults = _TransientFaults(key, _row(NAN))
    p0, s0 = fresh()
    pX, _, _ = run_scan(round_fn, _LinearSampler(), p0, s0, rounds=8,
                        key=key, chunk_size=2, faults=faults)
    assert not _finite(pX)

    ckpt = str(tmp_path / "sup")
    p0, s0 = fresh()
    p, s, hist, log = run_supervised(
        _launcher(round_fn, faults), p0, s0, rounds=8, key=key,
        config=SupervisorConfig(max_retries=3), ckpt_path=ckpt)
    assert _finite(p)
    assert len(hist["loss"]) == 8 and np.isfinite(hist["loss"]).all()
    assert len(log) == 1
    assert log[0]["retry"] == 1 and log[0]["t_resume"] == 4
    assert "non-finite" in log[0]["reason"]
    assert os.path.exists(ckpt + ".npz") and os.path.exists(ckpt + ".json")
    assert "1 rollback" in format_recovery_log(log)


def test_supervisor_exhausts_on_persistent_fault():
    """persistent=True keys the fault stream off its own seed, so rekeyed
    retries re-fire the same faults and the budget runs out."""
    _, _, round_fn, fresh = _safl_setup()
    faults = FaultConfig(num_clients=G, nan_rate=0.9, start=4, stop=6,
                         persistent=True)
    p0, s0 = fresh()
    with pytest.raises(SupervisorError) as e:
        run_supervised(_launcher(round_fn, faults), p0, s0, rounds=8,
                       key=jax.random.key(0),
                       config=SupervisorConfig(max_retries=2))
    assert len(e.value.log) == 2     # every attempted rollback is logged
    # first rollback resumes from the last good cursor; the repeat fault
    # distrusts that snapshot and deepens to the previous one
    assert e.value.log[0]["t_resume"] == 4
    assert e.value.log[1]["t_resume"] <= 4


def test_supervisor_clean_run_is_passthrough():
    """No faults: the supervised result equals the plain scan bitwise and
    the recovery log is empty."""
    _, _, round_fn, fresh = _safl_setup()
    key = jax.random.key(0)
    pA, sA, hA = _run(round_fn, fresh, chunk_size=2)
    p0, s0 = fresh()
    pB, sB, hB, log = run_supervised(
        _launcher(round_fn, None), p0, s0, rounds=8, key=key)
    _eq((pA, sA), (pB, sB))
    np.testing.assert_array_equal(hA["loss"], hB["loss"])
    assert log == []
    assert "clean run" in format_recovery_log(log)


def test_chunk_is_bad_verdicts():
    ok = {"loss": np.asarray([1.0, 0.5])}
    assert chunk_is_bad(ok) == (False, "")
    bad, why = chunk_is_bad({"loss": np.asarray([1.0, np.nan])})
    assert bad and "offset 1" in why
    bad, why = chunk_is_bad({"loss": np.asarray([1.0, 9.0])}, divergence=5.0)
    assert bad and "threshold" in why
    bad, why = chunk_is_bad({"loss": np.asarray([1.0]),
                             "diverged": np.asarray([1.0])})
    assert bad and "sentinel" in why


def test_acceptance_nan_plus_forced_divergence(tmp_path):
    """The ISSUE 7 acceptance scenario: a seeded run with persistent NaN
    payloads (handled per-round by the sentinel) AND a forced mid-run
    divergence -- an all-client Byzantine round under an SGD server, which
    defeats the median norm rule (breakdown point) and blows the loss past
    the divergence threshold -- completes via the supervisor with bounded
    retries and finite params.  (An adaptive server normalizes Byzantine
    scale away, hence the SGD server here.)  The divergence surfaces one
    chunk AFTER the corrupting round (detection lag: a round's loss
    predates its own update), so the first rollback cursor sits inside the
    blast radius and the supervisor must deepen to the previous snapshot."""
    from repro.core.adaptive import AdaConfig
    from repro.core.packed import make_packing_plan
    from repro.core.safl import SAFLConfig, safl_round

    base = SAFLConfig(sketch=_SK, server=AdaConfig(name="sgd", lr=0.5),
                      client_lr=0.05, local_steps=2)
    plan = make_packing_plan(_SK, _params0())
    key = jax.random.key(2)
    kd0 = np.asarray(jax.random.key_data(key))

    class Acceptance:
        def spec(self, t, base_key):
            codes = jnp.where(jnp.arange(G) == 2, NAN, OK)   # every round
            blow = (jnp.all(jax.random.key_data(base_key) == kd0)
                    & (t == 5))                              # original key
            codes = jnp.where(blow, BYZANTINE, codes)
            return _spec_from_codes(codes, jnp.float32(1e6))

    rf = functools.partial(safl_round, base, _linear_loss, plan=plan,
                           sentinel=SentinelConfig(norm_mult=10.0,
                                                   divergence=1e3))
    fresh = lambda: (_params0(), init_safl(base, _params0()))
    p0, s0 = fresh()
    p, s, hist, log = run_supervised(
        _launcher(rf, Acceptance()), p0, s0, rounds=8, key=key,
        config=SupervisorConfig(max_retries=4),
        ckpt_path=str(tmp_path / "acc"))
    assert _finite(p)
    assert len(hist["loss"]) == 8 and np.isfinite(hist["loss"]).all()
    assert (hist["loss"] < 1e3).all()
    assert hist["n_rejected"].sum() == 8     # the NaN client, every round
    assert [e["t_resume"] for e in log] == [6, 4]   # deepening rollback
    assert all("sentinel" in e["reason"] for e in log)
    assert os.path.exists(str(tmp_path / "acc") + ".npz")


# ---------------------------------------------------------------------------
# hypothesis properties (skipped when hypothesis is absent; the
# deterministic twins above keep the module alive for the junit check)
# ---------------------------------------------------------------------------

def _table_strategy():
    from hypothesis import strategies as st
    row = st.tuples(*[st.sampled_from([OK, DROP, NAN, INF, BYZANTINE])
                      for _ in range(G)])
    return st.lists(row, min_size=1, max_size=3).map(tuple)


def test_property_any_fault_pattern_keeps_params_finite():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings

    @settings(max_examples=10, deadline=None)
    @given(_table_strategy())
    def prop(codes):
        _, _, round_fn, fresh = _safl_setup()
        rf = functools.partial(round_fn, sentinel=SENT)
        p, s, h = _run(rf, fresh, rounds=4, chunk_size=4,
                       faults=FaultTable(codes=codes, byzantine_scale=1e4))
        assert _finite(p) and _finite(s)
        assert np.isfinite(h["loss"]).all()

    prop()


def test_property_nan_equals_drop():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    row = st.tuples(*[st.sampled_from([OK, NAN]) for _ in range(G)])

    @settings(max_examples=10, deadline=None)
    @given(st.lists(row, min_size=1, max_size=3).map(tuple))
    def prop(codes):
        _, _, round_fn, fresh = _safl_setup()
        rf = functools.partial(round_fn, sentinel=SENT)
        dropped = tuple(tuple(DROP if c == NAN else c for c in r)
                        for r in codes)
        pA, sA, hA = _run(rf, fresh, rounds=4, chunk_size=4,
                          faults=FaultTable(codes=codes))
        pB, sB, hB = _run(rf, fresh, rounds=4, chunk_size=4,
                          faults=FaultTable(codes=dropped))
        _eq((pA, sA), (pB, sB))
        np.testing.assert_array_equal(hA["loss"], hB["loss"])

    prop()
