"""Streamed client-microbatch sketch aggregation (DESIGN.md §12, ISSUE 9).

Pins the ``microbatch=`` contract across the aggregation spine:

  * ``resolve_microbatch`` routing: ``None`` / ``mb >= G`` resolve to the
    materialized path, which stays BITWISE identical to ``microbatch``
    absent (Python-level early return, no trace change);
  * the streamed fold (``mb < G``) reproduces the materialized cohort mean
    up to float summation order (allclose) for safl, clipped safl, fedopt,
    and the async staleness ring, under 0/1 masks, weighted dict masks,
    faults, and both sentinel modes (finite-only single pass and
    norm-outlier two-pass);
  * non-dividing ``G % mb != 0`` uses a masked zero-weight tail microbatch
    -- no pad-and-reorder -- so G=5, mb=2 equals the materialized round and
    pad rows are exactly inert;
  * per-microbatch hook indexing is GLOBAL: participation masks and fault
    specs slice to absolute client rows, so chunking never re-keys a
    client's stream;
  * driver threading: ``run_scan(microbatch=)`` == ``run_host_loop``
    bitwise, and ``uplink_bits`` counts the EFFECTIVE post-guard cohort
    (n_dropped/n_rejected subtracted) while no-fault histories stay
    bitwise-pinned;
  * the ``PackingPlan`` layer-chunk threshold path (leaves above
    ``SKETCH_CHUNK_NUMEL``): sk/desk parity of the chunked per-leaf route
    against the packed plan on a synthetic large-leaf tree.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import AdaConfig
from repro.core.clipped import ClippedSAFLConfig, clipped_safl_round
from repro.core.packed import (make_packing_plan, sk_packed_clients,
                               sk_packed_clients_wsum)
from repro.core.safl import (SAFLConfig, chunk_clients, fedopt_round,
                             init_safl, resolve_microbatch, safl_round,
                             uplink_bits_per_round)
from repro.core.sketch import SketchConfig
from repro.fed import (AsyncConfig, FaultConfig, FaultTable,
                       FullParticipation, SentinelConfig, init_async_state,
                       make_async_round)
from repro.fed import DROP as F_DROP
from repro.fed import NAN as F_NAN
from repro.fed import OK as F_OK
from repro.launch.driver import run_host_loop, run_scan

G = 5               # deliberately prime vs mb=2: forces the masked tail
MB = 2

_SK = SketchConfig(kind="countsketch", ratio=0.25, min_b=8)


def _loss(params, batch):
    return jnp.mean((batch["x"] @ params["W"] - batch["y"]) ** 2)


def _params0():
    return {"W": jnp.zeros((16, 4)), "b": jnp.zeros((4,))}


def _loss_b(params, batch):
    return jnp.mean(
        (batch["x"] @ params["W"] + params["b"] - batch["y"]) ** 2)


def _batch(g=G, seed=1):
    x = jax.random.normal(jax.random.key(seed), (g, 2, 4, 16))
    W = jax.random.normal(jax.random.key(2), (16, 4))
    return {"x": x, "y": x @ W}


def _cfg():
    return SAFLConfig(sketch=_SK, server=AdaConfig(name="amsgrad", lr=0.05),
                      client_lr=0.05, local_steps=2)


def _setup():
    cfg = _cfg()
    params = _params0()
    plan = make_packing_plan(_SK, params)
    return cfg, params, init_safl(cfg, params), plan, jax.random.key(7)


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _trees_close(a, b, rtol=3e-5, atol=3e-6):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def test_resolve_microbatch_routing():
    assert resolve_microbatch(None, G) is None
    assert resolve_microbatch(G, G) is None       # >= G: materialized path
    assert resolve_microbatch(G + 3, G) is None
    assert resolve_microbatch(2, G) == 2
    assert resolve_microbatch(1, G) == 1
    with pytest.raises(ValueError):
        resolve_microbatch(0, G)
    with pytest.raises(ValueError):
        resolve_microbatch(-1, G)


def test_microbatch_ge_g_is_bitwise_pinned():
    """microbatch=None and microbatch>=G are a Python-level early return:
    the round program -- and its outputs -- are bit-identical to the
    pre-microbatch rounds."""
    cfg, params, opt, plan, rk = _setup()
    batch = _batch()
    ref = safl_round(cfg, _loss_b, params, opt, batch, rk, plan=plan)
    for mb in (None, G, G + 1, 64):
        got = safl_round(cfg, _loss_b, params, opt, batch, rk, plan=plan,
                         microbatch=mb)
        _trees_equal(ref[0], got[0])
        _trees_equal(ref[1], got[1])
        np.testing.assert_array_equal(np.asarray(ref[2]["loss"]),
                                      np.asarray(got[2]["loss"]))


# ---------------------------------------------------------------------------
# streamed fold == materialized cohort mean (the tentpole)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mb", [1, 2, 3, 4])
def test_streamed_round_matches_materialized(mb):
    """Sketch linearity (Property 1): folding per-chunk weighted sketch
    sums reproduces the materialized cohort mean for every chunk size,
    dividing or not (mb=2,3,4 all leave a tail at G=5)."""
    cfg, params, opt, plan, rk = _setup()
    batch = _batch()
    ref = safl_round(cfg, _loss_b, params, opt, batch, rk, plan=plan)
    got = safl_round(cfg, _loss_b, params, opt, batch, rk, plan=plan,
                     microbatch=mb)
    _trees_close(ref[0], got[0])
    np.testing.assert_allclose(np.asarray(ref[2]["loss"]),
                               np.asarray(got[2]["loss"]),
                               rtol=3e-5, atol=3e-6)


def test_nondividing_tail_regression_g5_mb2():
    """ISSUE 9 satellite: G=5, mb=2 -- the masked zero-weight tail chunk
    must be exact (no pad-and-reorder, no weight leakage).  Appending a
    masked-out 6th client reproduces the same update: pad rows and
    masked-out real rows are equally inert."""
    cfg, params, opt, plan, rk = _setup()
    batch5 = _batch(5)
    ref = safl_round(cfg, _loss_b, params, opt, batch5, rk, plan=plan)
    got = safl_round(cfg, _loss_b, params, opt, batch5, rk, plan=plan,
                     microbatch=2)
    _trees_close(ref[0], got[0])

    batch6 = jax.tree.map(
        lambda x: jnp.concatenate([x, x[-1:]], axis=0), batch5)
    mask6 = jnp.array([1., 1., 1., 1., 1., 0.])
    got6 = safl_round(cfg, _loss_b, params, opt, batch6, rk, plan=plan,
                      part_mask=mask6, microbatch=2)
    _trees_close(got[0], got6[0])
    np.testing.assert_allclose(np.asarray(got[2]["loss"]),
                               np.asarray(got6[2]["loss"]),
                               rtol=3e-5, atol=3e-6)


def test_chunk_clients_layout():
    """chunk_clients pads on the CLIENT axis only and never reorders: row
    [i, j] of the chunked tree is global client i*mb + j."""
    x = jnp.arange(5 * 3, dtype=jnp.float32).reshape(5, 3)
    c = chunk_clients({"x": x}, 2, 1)["x"]
    assert c.shape == (3, 2, 3)
    np.testing.assert_array_equal(np.asarray(c[0]), np.asarray(x[0:2]))
    np.testing.assert_array_equal(np.asarray(c[2, 0]), np.asarray(x[4]))
    np.testing.assert_array_equal(np.asarray(c[2, 1]), np.zeros(3))


def test_sk_packed_clients_wsum_matches_materialized_sum():
    """The fused chunk reducer == materialize-then-weighted-sum."""
    _, params, _, plan, rk = _setup()
    from repro.core.packed import derive_round_params
    rp = derive_round_params(plan, rk)
    deltas = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(3), (4,) + p.shape),
        params)
    w = jnp.array([1.0, 0.0, 2.0, 0.5])
    s = sk_packed_clients(plan, rp, deltas).astype(jnp.float32)
    S, W = sk_packed_clients_wsum(plan, rp, deltas, w)
    np.testing.assert_allclose(np.asarray(S),
                               np.asarray(jnp.sum(s * w[:, None], axis=0)),
                               rtol=1e-6, atol=1e-6)
    assert float(W) == 3.5


# ---------------------------------------------------------------------------
# hooks under streaming: global client indexing
# ---------------------------------------------------------------------------

def test_streamed_mask_01_and_weighted():
    cfg, params, opt, plan, rk = _setup()
    batch = _batch()
    mask = jnp.array([1., 0., 1., 1., 0.])
    ref = safl_round(cfg, _loss_b, params, opt, batch, rk, plan=plan,
                     part_mask=mask)
    got = safl_round(cfg, _loss_b, params, opt, batch, rk, plan=plan,
                     part_mask=mask, microbatch=MB)
    _trees_close(ref[0], got[0])
    np.testing.assert_allclose(np.asarray(ref[2]["loss"]),
                               np.asarray(got[2]["loss"]),
                               rtol=3e-5, atol=3e-6)

    wm = {"w": jnp.array([0.5, 0., 2.0, 1.0, 0.]), "den": 3.5}
    ref = safl_round(cfg, _loss_b, params, opt, batch, rk, plan=plan,
                     part_mask=wm)
    got = safl_round(cfg, _loss_b, params, opt, batch, rk, plan=plan,
                     part_mask=wm, microbatch=MB)
    _trees_close(ref[0], got[0])


@pytest.mark.parametrize("norm_mult", [0.0, 3.0])
def test_streamed_faults_and_sentinel(norm_mult):
    """Faults + sentinel under streaming: the fault spec slices to GLOBAL
    client rows per chunk and the norm-outlier median (a cohort statistic)
    is computed over ALL clients via the two-pass fold -- update, loss and
    the n_dropped/n_rejected/diverged counters all match the materialized
    guard."""
    cfg, params, opt, plan, rk = _setup()
    batch = _batch()
    ft = FaultConfig(num_clients=G, drop_rate=0.25, nan_rate=0.2,
                     inf_rate=0.1, byzantine_rate=0.2, byzantine_scale=50.0)
    spec = ft.spec(jnp.asarray(3, jnp.int32), jax.random.key(9))
    sent = SentinelConfig(norm_mult=norm_mult, divergence=10.0)
    ref = safl_round(cfg, _loss_b, params, opt, batch, rk, plan=plan,
                     fault_spec=spec, sentinel=sent)
    got = safl_round(cfg, _loss_b, params, opt, batch, rk, plan=plan,
                     fault_spec=spec, sentinel=sent, microbatch=MB)
    _trees_close(ref[0], got[0])
    for k in ("loss", "n_dropped", "n_rejected", "diverged"):
        np.testing.assert_allclose(np.asarray(ref[2][k]),
                                   np.asarray(got[2][k]),
                                   rtol=3e-5, atol=3e-6)


def test_streamed_fedopt_and_clipped():
    cfg, params, opt, plan, rk = _setup()
    batch = _batch()
    mask = jnp.array([1., 0., 1., 1., 0.])
    ref = fedopt_round(cfg, _loss_b, params, opt, batch, rk, part_mask=mask)
    got = fedopt_round(cfg, _loss_b, params, opt, batch, rk, part_mask=mask,
                       microbatch=MB)
    _trees_close(ref[0], got[0])

    ccfg = ClippedSAFLConfig(base=cfg, clip_tau=0.05)
    ref = clipped_safl_round(ccfg, _loss_b, params, opt, batch, rk,
                             plan=plan)
    got = clipped_safl_round(ccfg, _loss_b, params, opt, batch, rk,
                             plan=plan, microbatch=MB)
    _trees_close(ref[0], got[0])


def test_streamed_telemetry_raises():
    """Telemetry probes read the materialized (G, ...) delta tree; the
    streamed fold never builds it -- the combination is a loud error, not a
    silent fallback."""
    from repro.obs.telemetry import Telemetry
    cfg, params, opt, plan, rk = _setup()
    with pytest.raises(ValueError, match="telemetry"):
        safl_round(cfg, _loss_b, params, opt, _batch(), rk, plan=plan,
                   telemetry=Telemetry(delta_norm=True), microbatch=MB)


def test_streamed_async_ring_matches():
    """The async staleness ring stages per-client payload rows; under
    streaming the rows are produced chunk-by-chunk at their GLOBAL offsets,
    so the ring push/pop sequence is identical (bitwise here: the staged
    sketches are computed by the same fused kernel either way)."""
    cfg, params, _, plan, _ = _setup()
    acfg = AsyncConfig(max_delay=2, delay="stagger")
    rf0 = make_async_round(cfg, _loss_b, acfg, plan)
    rf2 = make_async_round(cfg, _loss_b, acfg, plan, microbatch=MB)

    def run(rf):
        p = jax.tree.map(jnp.copy, params)
        st = init_async_state(cfg, acfg, p, plan, G)
        ms = []
        for t in range(4):
            b = jax.tree.map(
                lambda x: x + jnp.float32(t), _batch(seed=t + 1))
            p, st, m = rf(p, st, b, jax.random.fold_in(jax.random.key(7), t),
                          t=jnp.asarray(t, jnp.int32),
                          base_key=jax.random.key(11))
            ms.append(m)
        return p, ms

    pa, ma = run(rf0)
    pb, mb_ = run(rf2)
    _trees_close(pa, pb)
    for a, b in zip(ma, mb_):
        np.testing.assert_allclose(np.asarray(a["loss"]),
                                   np.asarray(b["loss"]),
                                   rtol=3e-5, atol=3e-6)


# ---------------------------------------------------------------------------
# driver threading
# ---------------------------------------------------------------------------

class _Sampler:
    def init_state(self):
        return {"W": jax.random.normal(jax.random.key(2), (16, 4))}

    def sample(self, state, t):
        x = jax.random.normal(jax.random.fold_in(jax.random.key(11), t),
                              (G, 2, 4, 16))
        return state, {"x": x, "y": x @ state["W"]}


def _round_fn_setup():
    cfg = _cfg()
    plan = make_packing_plan(_SK, _params0())
    rf = functools.partial(safl_round, cfg, _loss_b, plan=plan)
    fresh = lambda: (_params0(), init_safl(cfg, _params0()))
    return cfg, plan, rf, fresh


def test_run_scan_streamed_matches_host_loop_bitwise():
    """run_scan(microbatch=) and run_host_loop(microbatch=) bind the same
    partial into the same round program: bit-identical trajectories (the
    streamed analogue of the PR-2 scan == host-loop pin)."""
    _, _, rf, fresh = _round_fn_setup()
    key = jax.random.key(5)
    p1, s1, h1 = run_scan(rf, _Sampler(), *fresh(), rounds=4, key=key,
                          microbatch=MB)
    p2, s2, h2 = run_host_loop(rf, _Sampler(), *fresh(), rounds=4, key=key,
                               microbatch=MB)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _trees_equal(p1, p2)
    _trees_equal(s1, s2)


def test_run_scan_microbatch_none_pin_and_allclose():
    _, _, rf, fresh = _round_fn_setup()
    key = jax.random.key(5)
    p0, _, h0 = run_scan(rf, _Sampler(), *fresh(), rounds=4, key=key)
    pg, _, hg = run_scan(rf, _Sampler(), *fresh(), rounds=4, key=key,
                         microbatch=G + 7)      # >= G: the bitwise pin
    np.testing.assert_array_equal(h0["loss"], hg["loss"])
    _trees_equal(p0, pg)
    pm, _, hm = run_scan(rf, _Sampler(), *fresh(), rounds=4, key=key,
                         microbatch=MB)
    np.testing.assert_allclose(h0["loss"], hm["loss"], rtol=3e-5, atol=3e-6)
    _trees_close(p0, pm)


# ---------------------------------------------------------------------------
# uplink_bits counts the EFFECTIVE post-guard cohort (ISSUE 9 satellite)
# ---------------------------------------------------------------------------

def test_uplink_bits_effective_cohort_under_faults():
    """A dropped payload never transmits and a rejected one is discarded at
    ingest: neither may count toward the round's uplink spend.  With the
    counters present, masked runs bill n - n_dropped - n_rejected clients
    and maskless runs scale by the surviving fraction."""
    cfg, plan, rf, fresh = _round_fn_setup()
    bits = uplink_bits_per_round(cfg, _params0())
    key = jax.random.key(5)
    tbl = FaultTable(codes=((F_OK, F_DROP, F_NAN, F_OK, F_OK),) * 4)
    sent = SentinelConfig(norm_mult=0.0)
    rf_s = functools.partial(safl_round, cfg, _loss_b, plan=plan,
                             sentinel=sent)
    _, _, h = run_scan(rf_s, _Sampler(), *fresh(), rounds=4, key=key,
                       participation=FullParticipation(G), faults=tbl,
                       bits_per_round=bits)
    np.testing.assert_allclose(
        h["uplink_bits"],
        bits * (G - h["n_dropped"] - h["n_rejected"]))
    assert np.all(h["n_dropped"] == 1) and np.all(h["n_rejected"] == 1)

    _, _, hm = run_scan(rf_s, _Sampler(), *fresh(), rounds=4, key=key,
                        faults=tbl, bits_per_round=bits)
    np.testing.assert_allclose(
        hm["uplink_bits"],
        bits * (G - hm["n_dropped"] - hm["n_rejected"]) / G)


def test_uplink_bits_no_fault_path_pinned():
    """Without fault counters the billing is untouched: bits * n under a
    mask, bits per round maskless -- the pre-fix histories, bitwise."""
    cfg, _, rf, fresh = _round_fn_setup()
    bits = uplink_bits_per_round(cfg, _params0())
    key = jax.random.key(5)
    _, _, h = run_scan(rf, _Sampler(), *fresh(), rounds=4, key=key,
                       participation=FullParticipation(G),
                       bits_per_round=bits)
    np.testing.assert_array_equal(
        h["uplink_bits"], np.full(4, bits * G, np.float32))
    _, _, hm = run_scan(rf, _Sampler(), *fresh(), rounds=4, key=key,
                        bits_per_round=bits)
    np.testing.assert_array_equal(
        hm["uplink_bits"], np.full(4, bits, np.float32))


# ---------------------------------------------------------------------------
# PackingPlan layer-chunk threshold path (ISSUE 9 satellite)
# ---------------------------------------------------------------------------

def test_layer_chunk_threshold_sk_desk_parity(monkeypatch):
    """Force the per-leaf layer-chunk branch (leaf numel above
    SKETCH_CHUNK_NUMEL) on a synthetic stacked-layers tree and pin the
    chunked sk/desk against the unchunked whole-leaf route: sk_leaf_stacked
    folds each layer row with fold_in(key, j), the same chain
    sketch_tree/desketch_tree use for a list of per-layer leaves, so the
    two factorizations are BITWISE equal."""
    import repro.core.sketch as sketch_mod
    skcfg = SketchConfig(kind="countsketch", ratio=0.25, min_b=8)
    rows, cols = 4, 96
    leaf = jax.random.normal(jax.random.key(5), (rows, cols))
    lk = jax.random.fold_in(jax.random.key(3), 0)

    stacked = sketch_mod.sk_leaf_stacked(
        skcfg, lk, leaf.astype(jnp.float32))             # (rows, b)
    per_row = jnp.stack([
        sketch_mod.sk_leaf(skcfg, jax.random.fold_in(lk, j), leaf[j])
        for j in range(rows)])
    np.testing.assert_array_equal(np.asarray(stacked), np.asarray(per_row))

    back = sketch_mod.desk_leaf_stacked(skcfg, lk, stacked, cols)
    back_rows = jnp.stack([
        sketch_mod.desk_leaf(skcfg, jax.random.fold_in(lk, j),
                             stacked[j], cols) for j in range(rows)])
    np.testing.assert_array_equal(np.asarray(back), np.asarray(back_rows))


def test_layer_chunk_threshold_roundtrip_matches_unchunked(monkeypatch):
    """Dropping SKETCH_CHUNK_NUMEL below a (rows, cols) leaf flips
    launch.train's per-leaf route into the layer-chunk branch; the sk ->
    collect -> desk roundtrip must equal the whole-leaf (threshold
    untouched) roundtrip up to the sketch's own chunking -- on one device
    with no collective they are the same estimator family applied
    per-layer vs whole-leaf, so we pin shape/finiteness here and exactness
    of each branch against its own reference above."""
    import repro.launch.train as train_mod
    skcfg = SketchConfig(kind="countsketch", ratio=0.25, min_b=8)
    deltas = {"stack": jax.random.normal(jax.random.key(5), (1, 4, 96))}
    key = jax.random.key(3)

    out_big = train_mod._sketch_avg_desk_local(skcfg, (), deltas, key)
    monkeypatch.setattr(train_mod, "SKETCH_CHUNK_NUMEL", 128)
    out_small = train_mod._sketch_avg_desk_local(skcfg, (), deltas, key)
    assert out_small["stack"].shape == deltas["stack"].shape
    assert np.isfinite(np.asarray(out_small["stack"])).all()
    # the two factorizations differ only in the per-layer fold_in chain;
    # both are unbiased estimates of the same leaf
    assert not np.array_equal(np.asarray(out_big["stack"]),
                              np.asarray(out_small["stack"]))


def test_mesh_plan_disables_packed_route_above_threshold(monkeypatch):
    """_mesh_plan falls back to plan=None (per-leaf reference loop with
    layer chunking) when a local shard exceeds the threshold -- the packed
    plan would materialize the whole shard's hash temporaries at once."""
    import repro.launch.train as train_mod
    from repro.models import ModelConfig
    model = ModelConfig(name="thresh", arch_type="dense", num_layers=1,
                        d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                        vocab_size=64)
    cfg = _cfg()

    class _FakeMesh:
        # only dict(mesh.shape) is consulted: a 1-device "mesh" whose local
        # shard shapes equal the global ones
        shape = {"pod": 1, "data": 1, "model": 1}
        axis_names = ("pod", "data", "model")

    mesh = _FakeMesh()
    abstract, pspecs, plan = train_mod._mesh_plan(model, cfg, mesh,
                                                  "cross_device")
    assert plan is not None
    monkeypatch.setattr(train_mod, "SKETCH_CHUNK_NUMEL", 16)
    _, _, plan2 = train_mod._mesh_plan(model, cfg, mesh, "cross_device")
    assert plan2 is None
