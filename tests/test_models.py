"""Model-family tests: forward/loss/decode + prefill-vs-decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (ModelConfig, decode_step, forward, init_cache,
                          init_params, loss_fn)
from repro.models.model import encode_for_decode


def tiny_dense(**kw):
    base = dict(name="t", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64)
    base.update(kw)
    return ModelConfig(**base)


def _greedy_forward_logits(cfg, params, tokens, extra=None):
    batch = {"tokens": tokens}
    if extra:
        batch.update(extra)
    h, _ = forward(cfg, params, batch, remat=False)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (h @ head)[..., :cfg.vocab_size]


@pytest.mark.parametrize("cfg_kw", [
    {},                                        # plain GQA
    {"sliding_window": 8},                     # SWA
    {"attn_bias": True, "num_kv_heads": 4},    # MHA + bias
    {"tie_embeddings": True},
])
def test_decode_matches_forward_dense(cfg_kw):
    """Cached decode must reproduce the full-sequence forward logits."""
    cfg = tiny_dense(**cfg_kw)
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full = _greedy_forward_logits(cfg, params, toks)
    cache = init_cache(cfg, B, 32)
    outs = []
    for t in range(S):
        logits, cache = decode_step(cfg, params, cache, toks[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32))
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.array(dec), np.array(full),
                               rtol=2e-2, atol=2e-3)


def test_decode_matches_forward_ssm():
    cfg = ModelConfig(name="s", arch_type="ssm", num_layers=2, d_model=64,
                      vocab_size=64, ssm_state=8)
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, 64)
    full = _greedy_forward_logits(cfg, params, toks)
    cache = init_cache(cfg, B, 32)
    outs = []
    for t in range(S):
        logits, cache = decode_step(cfg, params, cache, toks[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32))
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.array(dec), np.array(full),
                               rtol=2e-2, atol=2e-3)


def test_decode_matches_forward_mla():
    cfg = ModelConfig(name="m", arch_type="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=64,
                      mla=True, q_lora_rank=32, kv_lora_rank=16,
                      qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16)
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, 64)
    full = _greedy_forward_logits(cfg, params, toks)
    cache = init_cache(cfg, B, 16)
    outs = []
    for t in range(S):
        logits, cache = decode_step(cfg, params, cache, toks[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32))
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.array(dec), np.array(full),
                               rtol=2e-2, atol=2e-3)


def test_swa_ring_buffer_beyond_window():
    """Decode past the window: ring buffer keeps only the last W keys and
    still matches the full forward (which masks to the window)."""
    cfg = tiny_dense(sliding_window=6)
    params = init_params(cfg, jax.random.key(0))
    B, S = 1, 16
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full = _greedy_forward_logits(cfg, params, toks)
    cache = init_cache(cfg, B, 6)   # cache = window slots only
    assert cache["layers"]["l0"]["k"].shape[2] == 6
    for t in range(S):
        logits, cache = decode_step(cfg, params, cache, toks[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.array(logits), np.array(full[:, -1]),
                               rtol=2e-2, atol=2e-3)


def test_swa_attention_is_windowed():
    """Changing tokens outside the window must not change the last logits."""
    cfg = tiny_dense(sliding_window=4, num_layers=1)
    params = init_params(cfg, jax.random.key(0))
    t1 = jnp.zeros((1, 12), jnp.int32)
    t2 = t1.at[:, 0].set(7)  # outside the window of the last position
    l1 = _greedy_forward_logits(cfg, params, t1)[:, -1]
    l2 = _greedy_forward_logits(cfg, params, t2)[:, -1]
    np.testing.assert_allclose(np.array(l1), np.array(l2), atol=1e-5)


def test_causality():
    """Future tokens must not affect earlier logits."""
    cfg = tiny_dense()
    params = init_params(cfg, jax.random.key(0))
    t1 = jnp.zeros((1, 8), jnp.int32)
    t2 = t1.at[:, -1].set(9)
    l1 = _greedy_forward_logits(cfg, params, t1)
    l2 = _greedy_forward_logits(cfg, params, t2)
    np.testing.assert_allclose(np.array(l1[:, :-1]), np.array(l2[:, :-1]),
                               atol=1e-5)


def test_moe_capacity_and_aux_loss():
    cfg = ModelConfig(name="moe", arch_type="moe", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                      num_experts=4, moe_top_k=2, moe_d_ff=32,
                      router_aux_weight=0.1)
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(2), (2, 16), 0, 64)
    l = loss_fn(cfg, params, {"tokens": toks})
    assert jnp.isfinite(l)
    # aux weight should contribute: same model, zero aux weight
    import dataclasses
    cfg0 = dataclasses.replace(cfg, router_aux_weight=0.0)
    l0 = loss_fn(cfg0, params, {"tokens": toks})
    assert float(l) > float(l0)


def test_whisper_encode_for_decode_consistency():
    cfg = ModelConfig(name="w", arch_type="audio", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=64,
                      norm_kind="ln", mlp_kind="gelu", pos_kind="sinusoidal",
                      encoder_layers=2, encoder_seq=12, cross_attention=True,
                      frontend="audio")
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 8
    audio = jax.random.normal(jax.random.key(3), (B, 12, 64))
    toks = jax.random.randint(jax.random.key(4), (B, S), 0, 64)
    full = _greedy_forward_logits(cfg, params, toks, {"audio_embeds": audio})
    cache = init_cache(cfg, B, 16)
    cache = encode_for_decode(cfg, params, cache, audio)
    for t in range(S):
        logits, cache = decode_step(cfg, params, cache, toks[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.array(logits), np.array(full[:, -1]),
                               rtol=2e-2, atol=2e-3)


def test_mamba_chunk_boundary_consistency():
    """Sequence crossing several scan chunks == one-token recurrence."""
    from repro.models import layers as L
    cfg = ModelConfig(name="s", arch_type="ssm", num_layers=1, d_model=32,
                      vocab_size=16, ssm_state=4)
    params = init_params(cfg, jax.random.key(0))
    p = jax.tree.map(lambda x: x[0], params["layers"]["l0"]["mamba"])
    B, S = 1, 20
    x = jax.random.normal(jax.random.key(5), (B, S, 32))
    import repro.models.layers as LL
    old = LL.MAMBA_CHUNK
    LL.MAMBA_CHUNK = 8   # force multiple chunks
    try:
        y_full = L.mamba(cfg, p, x)
    finally:
        LL.MAMBA_CHUNK = old
    cache = {"h": jnp.zeros((B, cfg.d_inner, 4)),
             "conv": jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner))}
    ys = []
    for t in range(S):
        yt, cache = L.mamba_decode(cfg, p, x[:, t], cache)
        ys.append(yt[:, 0])
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.array(y_full), np.array(y_dec),
                               rtol=2e-2, atol=2e-3)


def test_vlm_patch_positions_and_loss_mask():
    cfg = ModelConfig(name="v", arch_type="vlm", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                      pos_kind="mrope", mrope_sections=(4, 2, 2),
                      frontend="vision", num_frontend_tokens=4)
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(6), (2, 8), 0, 64)
    pe = jax.random.normal(jax.random.key(7), (2, 4, 32))
    l = loss_fn(cfg, params, {"tokens": toks, "patch_embeds": pe})
    assert jnp.isfinite(l)
