import gc

import jax
import pytest

# Tests run on the single CPU device.  The 512-device flag is set ONLY by
# launch/dryrun.py (see DESIGN §5) -- never here.
jax.config.update("jax_enable_x64", False)

_last_module = [None]


@pytest.fixture(autouse=True)
def _clear_jax_caches_between_modules(request):
    """The full suite jit-compiles ~10 architectures x several step kinds;
    without clearing, the accumulated executables exhaust host memory
    (observed: LLVM 'Cannot allocate memory' after ~120 tests)."""
    mod = request.module.__name__
    if _last_module[0] is not None and _last_module[0] != mod:
        jax.clear_caches()
        gc.collect()
    _last_module[0] = mod
    yield


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
