"""Packed sketch engine: parity vs the per-leaf reference + derive-once.

The per-leaf path (repro.core.sketch) is the reference implementation; the
packed engine (repro.core.packed) must reproduce it exactly -- same round
key, same per-leaf fold_in derivation, same values -- while deriving the
operator params once per (round, leaf) instead of once per (round, leaf,
side-of-the-round-trip).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packed as P
from repro.core import sketch as S

KINDS = ["countsketch", "srht", "gaussian"]
# (kind, extra SketchConfig kwargs): covers both count-sketch hash families
VARIANTS = [("countsketch", {}), ("countsketch", {"cs_hash": "independent"}),
            ("srht", {}), ("gaussian", {})]
V_IDS = ["countsketch_balanced", "countsketch_independent", "srht", "gaussian"]


def _tree():
    return {
        "w": jax.random.normal(jax.random.key(0), (12, 7), jnp.bfloat16),
        "b": jax.random.normal(jax.random.key(1), (5,)),       # raw (b >= n)
        "s": jnp.float32(2.0),                                 # scalar leaf
        "big": jax.random.normal(jax.random.key(2), (40, 25)),
        "big2": jax.random.normal(jax.random.key(3), (40, 25)),  # same-shape group
    }


def _cfg(kind, **kw):
    return S.SketchConfig(kind=kind, ratio=0.3, min_b=8, **kw)


def _ref_payload(cfg, key, tree):
    """Concatenated per-leaf reference sketches, in packed payload order."""
    return jnp.concatenate([
        l.reshape(-1) for l in jax.tree.leaves(S.sketch_tree(cfg, key, tree))])


@pytest.mark.parametrize("kind,kw", VARIANTS + [("none", {})],
                         ids=V_IDS + ["none"])
def test_sk_desk_parity_per_tensor(kind, kw):
    tree, key = _tree(), jax.random.key(9)
    cfg = _cfg(kind, **kw)
    plan = P.make_packing_plan(cfg, tree)
    rp = P.derive_round_params(plan, key)

    pay = P.sk_packed(plan, rp, tree)
    assert pay.shape == (plan.b_total,) and pay.dtype == cfg.transport_dtype
    np.testing.assert_allclose(np.array(pay, np.float32),
                               np.array(_ref_payload(cfg, key, tree),
                                        np.float32), atol=1e-5)

    out = P.desk_packed(plan, rp, pay)
    ref = S.desketch_tree(cfg, key, S.sketch_tree(cfg, key, tree), tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(np.array(a, np.float32),
                                   np.array(b, np.float32), atol=1e-4)


@pytest.mark.parametrize("kind,kw", VARIANTS, ids=V_IDS)
def test_parity_concat_mode(kind, kw):
    tree, key = _tree(), jax.random.key(11)
    cfg = _cfg(kind, mode="concat", **kw)
    plan = P.make_packing_plan(cfg, tree)
    rp = P.derive_round_params(plan, key)
    pay = P.sk_packed(plan, rp, tree)
    ref = S.sketch_tree(cfg, key, tree)
    np.testing.assert_allclose(np.array(pay, np.float32),
                               np.array(ref, np.float32), atol=1e-5)
    out = P.desk_packed(plan, rp, pay)
    ref_out = S.desketch_tree(cfg, key, ref, tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref_out)):
        np.testing.assert_allclose(np.array(a, np.float32),
                                   np.array(b, np.float32), atol=1e-4)


@pytest.mark.parametrize("kind,kw", VARIANTS, ids=V_IDS)
def test_parity_under_client_vmap(kind, kw):
    """vmap over the client axis == per-client per-leaf reference."""
    tree, key = _tree(), jax.random.key(13)
    cfg = _cfg(kind, **kw)
    plan = P.make_packing_plan(cfg, tree)
    rp = P.derive_round_params(plan, key)
    stacked = jax.tree.map(
        lambda l: jnp.stack([l, 2 * l.astype(jnp.float32).astype(l.dtype),
                             -l]), tree)
    got = P.sk_packed_clients(plan, rp, stacked)
    assert got.shape == (3, plan.b_total)
    want = jax.vmap(lambda t: _ref_payload(cfg, key, t))(stacked)
    np.testing.assert_allclose(np.array(got, np.float32),
                               np.array(want, np.float32), atol=1e-5)


@pytest.mark.parametrize("kind,kw", [("countsketch", {"cs_hash": "independent"}),
                                     ("srht", {})],
                         ids=["countsketch_independent", "srht"])
def test_parity_use_pallas(kind, kw):
    """The Pallas route (interpret=True on CPU) matches the jnp reference."""
    tree, key = _tree(), jax.random.key(17)
    cfg = _cfg(kind, use_pallas=True, **kw)
    cfg_ref = _cfg(kind, **kw)
    plan = P.make_packing_plan(cfg, tree)
    rp = P.derive_round_params(plan, key)
    pay = P.sk_packed(plan, rp, tree)
    np.testing.assert_allclose(np.array(pay),
                               np.array(_ref_payload(cfg_ref, key, tree)),
                               rtol=1e-3, atol=1e-3)
    out = P.desk_packed(plan, rp, pay)
    ref = S.desketch_tree(cfg_ref, key,
                          S.sketch_tree(cfg_ref, key, tree), tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.array(a, np.float32),
                                   np.array(b, np.float32),
                                   rtol=1e-3, atol=1e-3)


def test_parity_use_pallas_clients_batched():
    """Multi-client pallas path: ONE batched launch == vmapped reference."""
    tree, key = _tree(), jax.random.key(19)
    cfg = _cfg("countsketch", use_pallas=True, cs_hash="independent")
    plan = P.make_packing_plan(cfg, tree)
    rp = P.derive_round_params(plan, key)
    stacked = jax.tree.map(lambda l: jnp.stack([l, -l, 2 * l, 0 * l]), tree)
    got = P.sk_packed_clients(plan, rp, stacked)
    want = jax.vmap(
        lambda t: _ref_payload(_cfg("countsketch", cs_hash="independent"),
                               key, t))(stacked)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kind", KINDS)
def test_roundtrip_packed_jits(kind):
    tree, key = _tree(), jax.random.key(23)
    cfg = _cfg(kind)
    plan = P.make_packing_plan(cfg, tree)
    out = jax.jit(functools.partial(P.roundtrip_packed, plan))(key, tree)
    ref = S.roundtrip_tree(cfg, key, tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.array(a, np.float32),
                                   np.array(b, np.float32), atol=1e-4)


# ---------------------------------------------------------------------------
# derive-once: hashes/signs exist exactly once per (round, leaf)
# ---------------------------------------------------------------------------

def _count_calls(monkeypatch, name):
    counter = {"n": 0}
    orig = getattr(S, name)

    def wrapped(*a, **kw):
        counter["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(S, name, wrapped)
    monkeypatch.setattr(P, name, wrapped)
    return counter


def test_countsketch_hashes_derived_once_per_round(monkeypatch):
    """Packed round trip: one _cs_hashes derivation per (n, b) GROUP (the
    vmapped batch covers every leaf of the group); the per-leaf reference
    re-derives per leaf on BOTH sides of the round trip."""
    tree, key = _tree(), jax.random.key(29)
    cfg = _cfg("countsketch", cs_hash="independent")
    plan = P.make_packing_plan(cfg, tree)
    n_groups = len({(op.n, op.b) for op in plan.ops if not op.raw})
    n_leaves = sum(1 for op in plan.ops if not op.raw)
    assert n_groups < n_leaves  # the tree has same-shape leaves to batch

    counter = _count_calls(monkeypatch, "_cs_hashes")
    rp = P.derive_round_params(plan, key)
    P.desk_packed(plan, rp, P.sk_packed(plan, rp, tree))
    assert counter["n"] == n_groups, counter["n"]

    counter["n"] = 0
    S.desketch_tree(cfg, key, S.sketch_tree(cfg, key, tree), tree)
    assert counter["n"] == 2 * n_leaves, counter["n"]  # sk side + desk side


def test_srht_params_derived_once_per_round(monkeypatch):
    tree, key = _tree(), jax.random.key(31)
    cfg = _cfg("srht")
    plan = P.make_packing_plan(cfg, tree)
    n_groups = len({(op.n, op.b) for op in plan.ops if not op.raw})
    n_leaves = sum(1 for op in plan.ops if not op.raw)

    counter = _count_calls(monkeypatch, "_srht_params")
    rp = P.derive_round_params(plan, key)
    P.desk_packed(plan, rp, P.sk_packed(plan, rp, tree))
    assert counter["n"] == n_groups, counter["n"]

    counter["n"] = 0
    S.desketch_tree(cfg, key, S.sketch_tree(cfg, key, tree), tree)
    assert counter["n"] == 2 * n_leaves, counter["n"]


def test_balanced_params_derived_once_per_round(monkeypatch):
    """The default (balanced) family also derives once per (n, b) group per
    round trip, vs twice per leaf in the per-leaf loop."""
    tree, key = _tree(), jax.random.key(41)
    cfg = _cfg("countsketch")  # balanced is the default family
    plan = P.make_packing_plan(cfg, tree)
    n_groups = len({(op.n, op.b) for op in plan.ops if not op.raw})
    n_leaves = sum(1 for op in plan.ops if not op.raw)

    counter = _count_calls(monkeypatch, "_balanced_cs_params")
    rp = P.derive_round_params(plan, key)
    P.desk_packed(plan, rp, P.sk_packed(plan, rp, tree))
    assert counter["n"] == n_groups, counter["n"]

    counter["n"] = 0
    S.desketch_tree(cfg, key, S.sketch_tree(cfg, key, tree), tree)
    assert counter["n"] == 2 * n_leaves, counter["n"]


def test_sk_and_desk_share_cached_params():
    """sk side and desk side consume the SAME round-param arrays (no
    re-derivation anywhere in the round trip), and re-derivation with the
    same key is deterministic."""
    tree, key = _tree(), jax.random.key(37)
    plan = P.make_packing_plan(_cfg("countsketch", cs_hash="independent"), tree)
    rp1 = P.derive_round_params(plan, key)
    rp2 = P.derive_round_params(plan, key)
    np.testing.assert_array_equal(np.array(rp1["h"]), np.array(rp2["h"]))
    np.testing.assert_array_equal(np.array(rp1["s"]), np.array(rp2["s"]))


# ---------------------------------------------------------------------------
# plan bookkeeping
# ---------------------------------------------------------------------------

def test_plan_payload_matches_per_leaf_sizes():
    tree = _tree()
    for kind in KINDS + ["none"]:
        cfg = _cfg(kind)
        plan = P.make_packing_plan(cfg, tree)
        assert plan.b_total == sum(S.tree_sketch_sizes(cfg, tree))
        assert plan.d_total == sum(
            int(np.prod(l.shape)) if l.shape else 1
            for l in jax.tree.leaves(tree))


def test_total_sketch_bits_through_plan():
    cfg = S.SketchConfig(kind="countsketch", ratio=0.1, min_b=8)
    tree = {"a": jnp.zeros((100,)), "b": jnp.zeros((50, 10))}
    assert S.total_sketch_bits(cfg, tree) == \
        sum(S.tree_sketch_sizes(cfg, tree)) * 32
    # concat mode counts the single concatenated payload
    ccfg = S.SketchConfig(kind="countsketch", ratio=0.1, min_b=8, mode="concat")
    assert S.total_sketch_bits(ccfg, tree) == \
        S.leaf_sketch_size(600, ccfg) * 32


def test_pack_unpack_roundtrip_identity():
    tree = _tree()
    plan = P.make_packing_plan(_cfg("countsketch"), tree)
    flat = P.pack_tree(plan, tree)
    assert flat.shape == (plan.d_total,) and flat.dtype == jnp.float32
    out = P.unpack_tree(plan, flat)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(np.array(a, np.float32),
                                   np.array(b, np.float32), atol=1e-2)


def test_safl_round_matches_per_leaf_composition():
    """safl_round (packed) == the same round composed from the per-leaf
    reference ops -- the refactor changes the dispatch, not the math."""
    from repro.core.adaptive import AdaConfig, apply_update
    from repro.core.safl import SAFLConfig, client_delta, init_safl, safl_round

    key = jax.random.key(0)
    W = jax.random.normal(jax.random.fold_in(key, 1), (16, 4))
    x = jax.random.normal(jax.random.key(2), (32, 16))
    batch = jax.tree.map(
        lambda t: t.reshape(4, 2, 4, *t.shape[1:]), {"x": x, "y": x @ W})
    loss_fn = lambda p, b: jnp.mean((b["x"] @ p["W"] - b["y"]) ** 2)
    params = {"W": jnp.zeros((16, 4))}

    cfg = SAFLConfig(sketch=S.SketchConfig(kind="countsketch", ratio=0.5,
                                           min_b=4),
                     server=AdaConfig(name="amsgrad", lr=0.05),
                     client_lr=0.05, local_steps=2)
    rk = jax.random.key(77)
    p1, _, _ = safl_round(cfg, loss_fn, params, init_safl(cfg, params),
                          batch, rk)

    # reference composition with the per-leaf ops
    eta = jnp.asarray(cfg.client_lr, jnp.float32)
    deltas, _ = jax.vmap(
        lambda mb: client_delta(cfg, loss_fn, params, mb, eta))(batch)
    sks = jax.vmap(lambda d: S.sketch_tree(cfg.sketch, rk, d))(deltas)
    mbar = jax.tree.map(lambda s: jnp.mean(s, axis=0), sks)
    update = S.desketch_tree(cfg.sketch, rk, mbar, params)
    p2, _ = apply_update(cfg.server, init_safl(cfg, params), params, update)
    np.testing.assert_allclose(np.array(p1["W"]), np.array(p2["W"]),
                               atol=1e-5)
