"""End-to-end behaviour tests for the paper's system: a full SAFL training
run (data pipeline -> model -> sketch uplink -> AMSGrad server -> checkpoint
round-trip) on a small LM, asserting the loss actually decreases."""

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.adaptive import AdaConfig
from repro.core.safl import (SAFLConfig, init_safl, safl_round,
                             uplink_bits_per_round)
from repro.core.sketch import SketchConfig
from repro.data import BigramLMData, LMDataConfig
from repro.models import ModelConfig, init_params, loss_fn


def test_end_to_end_safl_training(tmp_path):
    model = ModelConfig(name="e2e", arch_type="dense", num_layers=2,
                        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                        vocab_size=128)
    safl = SAFLConfig(
        sketch=SketchConfig(kind="countsketch", ratio=0.05, min_b=16),
        server=AdaConfig(name="amsgrad", lr=0.01),
        client_lr=0.5, local_steps=2)
    data = BigramLMData(LMDataConfig(vocab_size=128, seq_len=32,
                                     num_clients=5, alpha=0.03))
    params = init_params(model, jax.random.key(0))
    opt = init_safl(safl, params)
    loss = lambda p, b: loss_fn(model, p, b)
    step = jax.jit(functools.partial(safl_round, safl, loss))

    d = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
    # the whole point of the paper: uplink << 32d bits
    assert uplink_bits_per_round(safl, params) < 0.1 * d * 32

    first = None
    for t in range(40):
        batch = data.round_batch(8, 2, seed=t)
        params, opt, m = step(params, opt, batch, jax.random.key(t))
        if first is None:
            first = float(m["loss"])
    final = float(m["loss"])
    assert np.isfinite(final)
    assert final < first - 0.3, (first, final)

    # checkpoint round-trip preserves the trained state exactly
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, {"params": params, "opt": opt}, step=40)
    restored, step_no = restore_checkpoint(path, {"params": params, "opt": opt})
    assert step_no == 40
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
