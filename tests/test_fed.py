"""repro.fed: participation policies, masked aggregation, async buffer.

Pins the ISSUE 3 contracts:
  * an all-ones participation mask reproduces the full-participation path
    BITWISE for safl, clipped safl, and the fetchsgd/topk_ef baselines
    under run_scan;
  * participation masks are pure functions of the absolute round index
    (chunk-split invariance) and always sample >= 1 client;
  * the async staleness buffer with delay=0 is bit-identical to the
    synchronous scan path, and scan == host loop under real delays;
  * the device-side Gaussian classification sampler is pinned bitwise to
    its host_round_batch mirror and rides the scan driver.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import AdaConfig
from repro.core.baselines import (BaselineConfig, baseline_round,
                                  init_baseline_state)
from repro.core.clipped import ClippedSAFLConfig, clipped_safl_round
from repro.core.packed import make_packing_plan
from repro.core.safl import (SAFLConfig, init_safl, masked_mean, safl_round,
                             uplink_bits_per_round)
from repro.core.sketch import SketchConfig
from repro.data import ClsDataConfig, GaussianClsData
from repro.fed import (AsyncConfig, AvailabilityTrace, FixedCohort,
                       FullParticipation, UniformParticipation,
                       init_async_state, make_async_round)
from repro.launch.driver import run_host_loop, run_scan

G = 4   # clients in the linear task


class _LinearSampler:
    """Minimal driver-protocol sampler over a linear regression task."""

    def __init__(self, clients=G, local_steps=2, mb=4):
        self.shape = (clients, local_steps, mb, 16)
        self.W = np.asarray(jax.random.normal(jax.random.key(1), (16, 4)))

    def init_state(self):
        return {"W": jnp.asarray(self.W, jnp.float32)}

    def sample(self, state, t):
        x = jax.random.normal(jax.random.fold_in(jax.random.key(11), t),
                              self.shape)
        return state, {"x": x, "y": x @ state["W"]}


def _linear_loss(params, batch):
    return jnp.mean((batch["x"] @ params["W"] - batch["y"]) ** 2)


def _params0():
    return {"W": jnp.zeros((16, 4))}


_SK = SketchConfig(kind="countsketch", ratio=0.25, min_b=8)


def _safl_setup(clip=False):
    base = SAFLConfig(sketch=_SK, server=AdaConfig(name="amsgrad", lr=0.05),
                      client_lr=0.05, local_steps=2)
    plan = make_packing_plan(_SK, _params0())
    if clip:
        cfg = ClippedSAFLConfig(base=base, clip_tau=0.5)
        round_fn = functools.partial(clipped_safl_round, cfg, _linear_loss,
                                     plan=plan)
    else:
        cfg = base
        round_fn = functools.partial(safl_round, cfg, _linear_loss, plan=plan)
    fresh = lambda: (_params0(), init_safl(base, _params0()))
    return cfg, plan, round_fn, fresh


def _baseline_setup(name):
    cfg = BaselineConfig(name=name, client_lr=0.05, local_steps=2,
                         topk_ratio=0.25, sketch=_SK,
                         server=AdaConfig(name="sgd", lr=0.5))
    plan = make_packing_plan(_SK, _params0())
    round_fn = functools.partial(baseline_round, cfg, _linear_loss, plan=plan)
    fresh = lambda: (_params0(),
                     init_baseline_state(cfg, _params0(), G, plan=plan))
    return cfg, plan, round_fn, fresh


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# masked aggregation: all-ones mask == full participation, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["safl", "clipped", "fetchsgd", "topk_ef",
                                  "fedavg"])
def test_all_ones_mask_is_full_participation_bitwise(algo):
    """Routing through the masked-aggregation path with an all-ones mask
    reproduces today's full-participation scan rows bit for bit."""
    if algo in ("safl", "clipped"):
        _, _, round_fn, fresh = _safl_setup(clip=algo == "clipped")
    else:
        _, _, round_fn, fresh = _baseline_setup(algo)
    key = jax.random.key(5)
    p1, s1, h1 = run_scan(round_fn, _LinearSampler(), *fresh(), rounds=4,
                          key=key)
    p2, s2, h2 = run_scan(round_fn, _LinearSampler(), *fresh(), rounds=4,
                          key=key, participation=FullParticipation(G))
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(s1, s2)


def test_partial_participation_changes_trajectory_but_stays_finite():
    _, _, round_fn, fresh = _safl_setup()
    key = jax.random.key(5)
    pol = UniformParticipation(G, frac=0.5, seed=3)
    p1, s1, h1 = run_scan(round_fn, _LinearSampler(), *fresh(), rounds=4,
                          key=key)
    p2, s2, h2 = run_scan(round_fn, _LinearSampler(), *fresh(), rounds=4,
                          key=key, participation=pol)
    assert np.isfinite(h2["loss"]).all()
    assert not np.array_equal(h1["loss"], h2["loss"])


def test_partial_participation_error_feedback_freezes_unsampled():
    """topk_ef: a client outside the cohort must keep its error memory
    untouched that round."""
    cfg, plan, round_fn, fresh = _baseline_setup("topk_ef")
    smp = _LinearSampler()
    params, state = fresh()
    _, batch = smp.sample(smp.init_state(), jnp.asarray(0, jnp.int32))
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    _, s2, _ = baseline_round(cfg, _linear_loss, params, state, batch,
                              jax.random.key(0), plan=plan, part_mask=mask)
    for e_new, e_old in zip(jax.tree.leaves(s2["err"]),
                            jax.tree.leaves(state["err"])):
        # unsampled clients 1 and 3: error memory unchanged (zeros at t=0)
        np.testing.assert_array_equal(np.asarray(e_new)[1], np.asarray(e_old)[1])
        np.testing.assert_array_equal(np.asarray(e_new)[3], np.asarray(e_old)[3])
        # sampled clients accumulated a residual
        assert np.abs(np.asarray(e_new)[0]).sum() > 0


# ---------------------------------------------------------------------------
# policies: determinism, cohort guarantees, cohort-size accounting
# ---------------------------------------------------------------------------

def test_participation_mask_deterministic_across_chunk_splits():
    _, _, round_fn, fresh = _safl_setup()
    key = jax.random.key(7)
    pol = UniformParticipation(G, frac=0.5, seed=9)
    p1, s1, h1 = run_scan(round_fn, _LinearSampler(), *fresh(), rounds=4,
                          key=key, participation=pol, bits_per_round=100)
    p2, s2, h2 = run_scan(round_fn, _LinearSampler(), *fresh(), rounds=4,
                          key=key, participation=pol, bits_per_round=100,
                          chunk_size=2)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(s1, s2)
    # uplink bits reported for the SAMPLED cohort: per-client bits x cohort
    np.testing.assert_array_equal(h1["uplink_bits"], np.full(4, 200.0))


def test_uniform_policy_samples_exact_cohort_every_round():
    pol = UniformParticipation(5, frac=0.4, seed=0)
    masks = np.asarray(jax.vmap(pol.mask)(jnp.arange(50)))
    assert pol.cohort_size == 2
    np.testing.assert_array_equal(masks.sum(axis=1), np.full(50, 2.0))
    # not constant: different rounds sample different cohorts
    assert len({tuple(r) for r in masks}) > 1
    # pure function of (round, seed): a fresh policy object agrees
    masks2 = np.asarray(jax.vmap(UniformParticipation(5, frac=0.4, seed=0)
                                 .mask)(jnp.arange(50)))
    np.testing.assert_array_equal(masks, masks2)


def test_availability_trace_round_robin():
    pol = AvailabilityTrace.round_robin(5, groups=2)
    m = np.asarray(jax.vmap(pol.mask)(jnp.arange(4)))
    np.testing.assert_array_equal(m[0], [1, 0, 1, 0, 1])
    np.testing.assert_array_equal(m[1], [0, 1, 0, 1, 0])
    np.testing.assert_array_equal(m[0], m[2])     # period 2
    assert pol.cohort_size == 3


def test_fixed_cohort_mask():
    pol = FixedCohort(4, clients=(1, 3))
    np.testing.assert_array_equal(np.asarray(pol.mask(jnp.asarray(0))),
                                  [0, 1, 0, 1])
    assert pol.cohort_size == 2


def test_policies_reject_empty_cohorts():
    """Satellite guard: a policy can never produce a zero-client round."""
    with pytest.raises(AssertionError):
        UniformParticipation(4, frac=0.0)
    with pytest.raises(AssertionError):
        FixedCohort(4, clients=())
    with pytest.raises(AssertionError):
        AvailabilityTrace(trace=((1.0, 0.0), (0.0, 0.0)))
    # frac small enough to round to zero still samples one client
    assert UniformParticipation(5, frac=0.01).cohort_size == 1


def test_masked_mean_zero_mask_guard():
    """The masked-mean denominator is guarded: an (impossible-by-policy)
    all-zero mask yields a zero update, not NaN."""
    x = jnp.ones((4, 3))
    out = np.asarray(masked_mean(x, jnp.zeros((4,))))
    np.testing.assert_array_equal(out, np.zeros((3,)))


def test_uplink_bits_reports_sampled_cohort():
    cfg = SAFLConfig(sketch=_SK)
    params = _params0()
    per_client = uplink_bits_per_round(cfg, params)
    assert uplink_bits_per_round(cfg, params, cohort_size=3) == 3 * per_client
    with pytest.raises(AssertionError):
        uplink_bits_per_round(cfg, params, cohort_size=0)


# ---------------------------------------------------------------------------
# async staleness buffer
# ---------------------------------------------------------------------------

def test_async_delay_zero_is_synchronous_bitwise():
    """The satellite pin: a delay=0 buffer reproduces the synchronous scan
    path bit for bit (params, opt state, loss history)."""
    cfg, plan, round_fn, fresh = _safl_setup()
    acfg = AsyncConfig(max_delay=2, delay="zero")
    arf = make_async_round(cfg, _linear_loss, acfg, plan)
    afresh = lambda: (_params0(),
                      init_async_state(cfg, acfg, _params0(), plan, G))
    key = jax.random.key(5)
    p1, s1, h1 = run_scan(round_fn, _LinearSampler(), *fresh(), rounds=6,
                          key=key)
    p2, s2, h2 = run_scan(arf, _LinearSampler(), *afresh(), rounds=6,
                          key=key, buffer=True)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(s1, s2["opt"])
    # every round drains its full cohort immediately
    np.testing.assert_array_equal(h2["arrival_weight"], np.full(6, float(G)))


@pytest.mark.parametrize("kind", ["stagger", "uniform"])
def test_async_scan_matches_host_loop_bitwise(kind):
    cfg, plan, _, _ = _safl_setup()
    acfg = AsyncConfig(max_delay=2, delay=kind, staleness_alpha=0.5)
    arf = make_async_round(cfg, _linear_loss, acfg, plan)
    afresh = lambda: (_params0(),
                      init_async_state(cfg, acfg, _params0(), plan, G))
    key = jax.random.key(5)
    p1, s1, h1 = run_host_loop(arf, _LinearSampler(), *afresh(), rounds=6,
                               key=key, buffer=True, donate=False)
    p2, s2, h2 = run_scan(arf, _LinearSampler(), *afresh(), rounds=6,
                          key=key, buffer=True, chunk_size=3)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    np.testing.assert_array_equal(h1["arrival_weight"], h2["arrival_weight"])
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(s1, s2)
    assert np.isfinite(h2["loss"]).all()


def test_async_stale_arrivals_are_discounted():
    """With delays > 0 the total arrival weight of a full cohort is below G
    (stale payloads are (1+d)^-alpha-discounted), and early rounds see
    partial cohorts."""
    cfg, plan, _, _ = _safl_setup()
    acfg = AsyncConfig(max_delay=2, delay="stagger", staleness_alpha=0.5)
    arf = make_async_round(cfg, _linear_loss, acfg, plan)
    afresh = lambda: (_params0(),
                      init_async_state(cfg, acfg, _params0(), plan, G))
    _, _, h = run_scan(arf, _LinearSampler(), *afresh(), rounds=6,
                       key=jax.random.key(0), buffer=True)
    w = np.asarray(h["arrival_weight"])
    assert w[0] < G                       # round 0: delayed clients missing
    assert (w[2:] < G).all() and (w[2:] > 0).all()   # steady state: discounted


def test_async_composes_with_participation():
    """Cohort sampling gates what enters the buffer; the run stays finite
    and deterministic across chunk splits."""
    cfg, plan, _, _ = _safl_setup(clip=True)
    acfg = AsyncConfig(max_delay=1, delay="uniform")
    arf = make_async_round(cfg, _linear_loss, acfg, plan)
    afresh = lambda: (_params0(),
                      init_async_state(cfg, acfg, _params0(), plan, G))
    pol = UniformParticipation(G, frac=0.5, seed=1)
    key = jax.random.key(3)
    _, s1, h1 = run_scan(arf, _LinearSampler(), *afresh(), rounds=4, key=key,
                         buffer=True, participation=pol)
    _, s2, h2 = run_scan(arf, _LinearSampler(), *afresh(), rounds=4, key=key,
                         buffer=True, participation=pol, chunk_size=2)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(s1, s2)
    assert np.isfinite(h1["loss"]).all()
    # arrivals can stack across generations, but each generation contributes
    # at most cohort_size * (1+d)^-alpha weight
    bound = pol.cohort_size * sum(
        (1.0 + d) ** -acfg.staleness_alpha
        for d in range(acfg.buffer_rounds))
    assert (np.asarray(h1["arrival_weight"]) <= bound + 1e-6).all()


# ---------------------------------------------------------------------------
# device-side Gaussian classification sampler (ROADMAP satellite)
# ---------------------------------------------------------------------------

def _cls_data():
    return GaussianClsData(ClsDataConfig(num_features=8, num_classes=4,
                                         num_clients=3, dirichlet_alpha=0.5,
                                         seed=2))


def _cls_loss(params, batch):
    logits = batch["x"] @ params["W"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def test_gaussian_device_sampler_matches_host_bitwise():
    smp = _cls_data().device_sampler(batch_per_client=6, local_steps=2)
    for t in (0, 4):
        dev, host = smp.round_batch(t), smp.host_round_batch(t)
        np.testing.assert_array_equal(np.asarray(dev["x"]), host["x"])
        np.testing.assert_array_equal(np.asarray(dev["y"]), host["y"])
    assert host["x"].shape == (3, 2, 3, 8)
    assert host["y"].shape == (3, 2, 3)
    assert host["y"].min() >= 0 and host["y"].max() < 4


def test_gaussian_device_sampler_pure_in_round_seed():
    smp = _cls_data().device_sampler(batch_per_client=4, local_steps=2)
    b1 = np.asarray(smp.round_batch(5)["x"])
    # fresh sampler over the same dataset: identical
    smp2 = _cls_data().device_sampler(batch_per_client=4, local_steps=2)
    np.testing.assert_array_equal(b1, np.asarray(smp2.round_batch(5)["x"]))
    # different round: different draws
    assert not np.array_equal(b1, np.asarray(smp.round_batch(6)["x"]))
    # different clients draw different streams
    assert not np.array_equal(b1[0], b1[1])


def test_gaussian_workload_rides_scan_driver_bitwise():
    """Classification workloads run through run_scan and match the host
    loop bit for bit -- the protocol contract the bigram sampler pins."""
    smp = _cls_data().device_sampler(batch_per_client=6, local_steps=2)
    params0 = {"W": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    cfg = SAFLConfig(sketch=SketchConfig(kind="countsketch", ratio=0.5,
                                         min_b=4),
                     server=AdaConfig(name="amsgrad", lr=0.05),
                     client_lr=0.2, local_steps=2)
    plan = make_packing_plan(cfg.sketch, params0)
    round_fn = functools.partial(safl_round, cfg, _cls_loss, plan=plan)
    fresh = lambda: (jax.tree.map(jnp.copy, params0),
                     init_safl(cfg, params0))
    key = jax.random.key(9)
    p1, s1, h1 = run_host_loop(round_fn, smp, *fresh(), rounds=4, key=key,
                               donate=False)
    p2, s2, h2 = run_scan(round_fn, smp, *fresh(), rounds=4, key=key,
                          chunk_size=2)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(p1, p2)
    assert np.isfinite(h2["loss"]).all()


# ---------------------------------------------------------------------------
# importance-weighted sampling (ISSUE 4 satellite, ROADMAP follow-on)
# ---------------------------------------------------------------------------

def test_importance_uniform_probs_pins_to_uniform_policy_bitwise():
    """Uniform probabilities are the identity tilt with unit weights: a full
    scanned SAFL run under ImportanceParticipation reproduces the existing
    UniformParticipation trajectory bit for bit."""
    from repro.fed import ImportanceParticipation
    _, _, round_fn, fresh = _safl_setup()
    key = jax.random.key(9)
    uni = UniformParticipation(G, frac=0.5, seed=17)
    imp = ImportanceParticipation(G, probs=(0.25,) * G, frac=0.5, seed=17)
    assert imp.uniform and imp.cohort_size == uni.cohort_size
    p1, s1, h1 = run_scan(round_fn, _LinearSampler(), *fresh(), rounds=4,
                          key=key, participation=uni, bits_per_round=64)
    p2, s2, h2 = run_scan(round_fn, _LinearSampler(), *fresh(), rounds=4,
                          key=key, participation=imp, bits_per_round=64)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    np.testing.assert_array_equal(h1["uplink_bits"], h2["uplink_bits"])
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(s1, s2)


def test_importance_rejects_saturated_inclusion_regime():
    """m * max(p) > 1 means an inclusion probability would exceed 1; the
    pi_c ~= m p_c weights are then severely biased, so the constructor must
    reject the configuration loudly."""
    from repro.fed import ImportanceParticipation
    with pytest.raises(AssertionError, match="biased"):
        ImportanceParticipation(4, probs=(0.7, 0.1, 0.1, 0.1), frac=0.5)
    # m = 1 is always valid, whatever the skew
    ImportanceParticipation(4, probs=(0.7, 0.1, 0.1, 0.1), frac=0.25)


def test_importance_mask_weights_are_inverse_probability():
    """Sampled clients carry exactly 1/(N p_c); the rest carry 0; the static
    denominator and cohort count are the cohort size m."""
    from repro.fed import ImportanceParticipation
    probs = (0.4, 0.3, 0.2, 0.1)
    pol = ImportanceParticipation(4, probs=probs, frac=0.5, seed=5)
    for t in range(6):
        m = pol.mask(jnp.asarray(t, jnp.int32))
        w = np.asarray(m["w"])
        sel = w > 0
        assert sel.sum() == pol.cohort_size == m["n"]
        assert m["den"] == float(pol.cohort_size)
        np.testing.assert_allclose(
            w[sel], (1.0 / (4 * np.asarray(probs)))[sel], rtol=1e-6)


def test_importance_reweighting_corrects_cohort_mean_bias():
    """Over many rounds the 1/(N p_c)-weighted masked_mean tracks the true
    client mean far better than the unweighted cohort mean, which
    systematically over-represents high-probability clients.  (Exactly
    unbiased under pi_c ~= m p_c; at this skew the residual approximation
    bias is ~0.22 vs the cohort mean's ~0.40 -- both pinned loosely.)"""
    from repro.fed import ImportanceParticipation
    probs = (0.4, 0.3, 0.2, 0.1)
    pol = ImportanceParticipation(4, probs=probs, frac=0.5, seed=5)
    x = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    ts = jnp.arange(4000, dtype=jnp.int32)
    ws = jax.vmap(lambda t: pol.mask(t)["w"])(ts)               # (T, G)
    est_w = np.asarray(jnp.sum(ws * x[None, :], axis=1)) / 2.0
    sel = np.asarray(ws > 0, np.float64)
    est_unw = (sel * np.asarray(x)[None, :]).sum(axis=1) / 2.0
    true = 2.5
    assert abs(est_w.mean() - true) < 0.3
    assert abs(est_w.mean() - true) < abs(est_unw.mean() - true)


def test_importance_exact_unbiased_at_cohort_one():
    """At m = 1 the exponential-race inclusion probability is exactly p_c,
    so the Horvitz-Thompson estimate is exactly unbiased -- the empirical
    mean over rounds converges to the true mean."""
    from repro.fed import ImportanceParticipation
    probs = (0.4, 0.3, 0.2, 0.1)
    pol = ImportanceParticipation(4, probs=probs, frac=0.25, seed=11)
    assert pol.cohort_size == 1
    x = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    ts = jnp.arange(4000, dtype=jnp.int32)
    ws = jax.vmap(lambda t: pol.mask(t)["w"])(ts)
    est = np.asarray(jnp.sum(ws * x[None, :], axis=1)) / 1.0
    assert abs(est.mean() - 2.5) < 0.25


def test_importance_rides_scan_driver_and_freezes_ef_memory():
    """A skewed importance policy runs through run_scan for safl AND for the
    error-feedback topk_ef baseline (weighted masks route through
    mask_weights in the EF freeze), matching the host loop bitwise."""
    from repro.fed import ImportanceParticipation
    pol = ImportanceParticipation(G, probs=(0.4, 0.3, 0.2, 0.1), frac=0.5,
                                  seed=3)
    for setup in (lambda: _safl_setup()[2:], lambda: _baseline_setup("topk_ef")[2:]):
        round_fn, fresh = setup()
        key = jax.random.key(21)
        p1, s1, h1 = run_host_loop(round_fn, _LinearSampler(), *fresh(),
                                   rounds=4, key=key, donate=False,
                                   participation=pol)
        p2, s2, h2 = run_scan(round_fn, _LinearSampler(), *fresh(),
                              rounds=4, key=key, chunk_size=2,
                              participation=pol)
        assert np.isfinite(h2["loss"]).all()
        np.testing.assert_array_equal(h1["loss"], h2["loss"])
        _assert_trees_equal(p1, p2)
        _assert_trees_equal(s1, s2)


def test_async_buffer_rejects_weighted_masks():
    """The staleness buffer stores 0/1 cohort masks per generation; weighted
    importance masks must be rejected at trace time, not silently mis-
    aggregated."""
    from repro.fed import ImportanceParticipation
    base = SAFLConfig(sketch=_SK, server=AdaConfig(name="amsgrad", lr=0.05),
                      client_lr=0.05, local_steps=2)
    plan = make_packing_plan(_SK, _params0())
    acfg = AsyncConfig(max_delay=1, delay="zero")
    round_fn = make_async_round(base, _linear_loss, acfg, plan)
    pol = ImportanceParticipation(G, probs=(0.4, 0.3, 0.2, 0.1), frac=0.5)
    params = _params0()
    state = init_async_state(base, acfg, params, plan, G)
    smp = _LinearSampler()
    _, batch = smp.sample(smp.init_state(), jnp.asarray(0, jnp.int32))
    with pytest.raises(TypeError, match="weighted"):
        round_fn(params, state, batch, jax.random.key(0),
                 t=jnp.asarray(0, jnp.int32), base_key=jax.random.key(0),
                 part_mask=pol.mask(jnp.asarray(0, jnp.int32)))
