"""Unit tests for the sketching operators (paper §3.2 Properties 1-3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch as S

KINDS = ["gaussian", "srht", "countsketch"]


def _cfg(kind, ratio=0.5, **kw):
    return S.SketchConfig(kind=kind, ratio=ratio, min_b=8, **kw)


@pytest.mark.parametrize("kind", KINDS)
def test_linearity_exact(kind):
    """Property 1: sk(av + bw) == a sk(v) + b sk(w) (same seed) exactly."""
    cfg = _cfg(kind)
    key = jax.random.key(3)
    v = jax.random.normal(jax.random.key(1), (300,))
    w = jax.random.normal(jax.random.key(2), (300,))
    lhs = S.sk_leaf(cfg, key, 2.0 * v - 3.0 * w)
    rhs = 2.0 * S.sk_leaf(cfg, key, v) - 3.0 * S.sk_leaf(cfg, key, w)
    np.testing.assert_allclose(np.array(lhs), np.array(rhs), atol=1e-4)


@pytest.mark.parametrize("kind", KINDS)
def test_desk_linearity(kind):
    cfg = _cfg(kind)
    key = jax.random.key(4)
    b = S.leaf_sketch_size(200, cfg)
    s1 = jax.random.normal(jax.random.key(5), (b,))
    s2 = jax.random.normal(jax.random.key(6), (b,))
    lhs = S.desk_leaf(cfg, key, s1 + s2, 200)
    rhs = S.desk_leaf(cfg, key, s1, 200) + S.desk_leaf(cfg, key, s2, 200)
    np.testing.assert_allclose(np.array(lhs), np.array(rhs), atol=1e-4)


@pytest.mark.parametrize("kind", KINDS)
def test_unbiasedness(kind):
    """Property 2: E[desk(sk(v))] == v, estimated over many seeds."""
    cfg = _cfg(kind, ratio=0.5)
    v = jax.random.normal(jax.random.key(7), (128,))
    n_trials = 600
    acc = jnp.zeros_like(v)
    for t in range(n_trials):
        key = jax.random.key(100 + t)
        acc = acc + S.desk_leaf(cfg, key, S.sk_leaf(cfg, key, v), 128)
    mean = acc / n_trials
    rel = float(jnp.linalg.norm(mean - v) / jnp.linalg.norm(v))
    # std of the mean ~ sqrt(n/b / T) ~ sqrt(2/600) ~ 0.06
    assert rel < 0.2, rel


@pytest.mark.parametrize("kind", KINDS)
def test_inner_product_concentration(kind):
    """Property 3: <desk(sk(v)), h> concentrates around <v, h>."""
    cfg = _cfg(kind, ratio=0.5)
    v = jax.random.normal(jax.random.key(8), (256,))
    h = jax.random.normal(jax.random.key(9), (256,))
    target = float(v @ h)
    scale = float(jnp.linalg.norm(v) * jnp.linalg.norm(h))
    errs = []
    for t in range(100):
        key = jax.random.key(200 + t)
        rt = S.desk_leaf(cfg, key, S.sk_leaf(cfg, key, v), 256)
        errs.append(abs(float(rt @ h) - target) / scale)
    # median deviation should be well under ~ 1/sqrt(b) * polylog
    assert float(np.median(errs)) < 0.5, np.median(errs)


def test_sketch_sizes_and_bits():
    cfg = _cfg("countsketch", ratio=0.1)
    tree = {"a": jnp.zeros((100,)), "b": jnp.zeros((50, 10))}
    sizes = S.tree_sketch_sizes(cfg, tree)
    assert sizes == [10, 50]
    assert S.total_sketch_bits(cfg, tree) == (10 + 50) * 32


def test_none_kind_identity():
    cfg = S.SketchConfig(kind="none")
    v = jnp.arange(16.0)
    assert jnp.allclose(S.sk_leaf(cfg, jax.random.key(0), v), v)


def test_tree_roundtrip_shapes_dtypes():
    cfg = _cfg("countsketch", ratio=0.3)
    tree = {"w": jnp.ones((12, 7), jnp.bfloat16), "b": jnp.ones((5,))}
    rt = S.roundtrip_tree(cfg, jax.random.key(0), tree)
    assert rt["w"].shape == (12, 7) and rt["w"].dtype == jnp.bfloat16
    assert rt["b"].shape == (5,)


def test_concat_mode_matches_paper_algorithm():
    """concat mode sketches the full concatenated vector (Alg. 1 verbatim)."""
    cfg = S.SketchConfig(kind="countsketch", ratio=0.5, min_b=8, mode="concat")
    tree = {"a": jnp.arange(10.0), "b": jnp.ones((4, 4))}
    sk = S.sketch_tree(cfg, jax.random.key(1), tree)
    assert sk.ndim == 1 and sk.shape[0] == S.leaf_sketch_size(26, cfg)
    rt = S.desketch_tree(cfg, jax.random.key(1), sk, tree)
    assert rt["a"].shape == (10,) and rt["b"].shape == (4, 4)


def test_fwht_orthogonality():
    """H H^T = n I for the unnormalized transform."""
    n = 64
    eye = jnp.eye(n)
    H = jax.vmap(S.fwht)(eye)
    np.testing.assert_allclose(np.array(H @ H.T), n * np.eye(n), atol=1e-3)


def test_fwht_matches_reference():
    for n in (4, 32, 256):
        x = np.random.RandomState(0).randn(n).astype(np.float32)
        np.testing.assert_allclose(
            np.array(S.fwht(jnp.array(x))), S.fwht_reference(x), rtol=1e-4)


def test_transport_dtype_bf16():
    """Beyond-paper: bf16 sketch transport halves uplink bits."""
    cfg32 = _cfg("countsketch", ratio=0.25)
    cfg16 = _cfg("countsketch", ratio=0.25, transport_dtype=jnp.bfloat16)
    tree = {"w": jnp.zeros((1000,))}
    assert S.total_sketch_bits(cfg16, tree) * 2 == S.total_sketch_bits(cfg32, tree)
    sk = S.sketch_tree(cfg16, jax.random.key(0), tree)
    assert jax.tree.leaves(sk)[0].dtype == jnp.bfloat16
