"""Sharding rules, input specs, hlo_costs parser, and a subprocess
mini-dry-run on an 8-device host mesh (integration proof that the
distributed train/serve steps lower and compile)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, input_specs, shape_eligible
from repro.models import param_pspecs
from repro.models.model import param_shapes
from repro.models.sharding import hint, use_mesh


def _abstract(cfg):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(tuple(s), cfg.dtype),
                        param_shapes(cfg), is_leaf=lambda x: isinstance(x, tuple))


def test_param_pspec_rules():
    cfg = get_config("llama3_2_1b")
    pa = _abstract(cfg)
    specs = param_pspecs(pa, fsdp=False)
    assert specs["embed"] == P("model", None)
    assert specs["layers"]["l0"]["attn"]["wq"] == P(None, None, "model")
    assert specs["layers"]["l0"]["attn"]["wo"] == P(None, "model", None)
    assert specs["layers"]["l0"]["mlp"]["wi"] == P(None, None, "model")
    # fsdp adds the data axis
    specs2 = param_pspecs(pa, fsdp=True)
    assert specs2["layers"]["l0"]["attn"]["wq"] == P(None, "data", "model")


def test_moe_and_mamba_pspecs():
    moe = param_pspecs(_abstract(get_config("dbrx_132b")), fsdp=True)
    assert moe["layers"]["l0"]["moe"]["wi"] == P(None, "model", "data", None)
    ssm = param_pspecs(_abstract(get_config("falcon_mamba_7b")), fsdp=False)
    assert ssm["layers"]["l0"]["mamba"]["wx"] == P(None, None, "model")
    assert ssm["layers"]["l0"]["mamba"]["a_log"] == P(None, "model", None)


def test_hint_noop_off_mesh():
    x = jnp.ones((4, 4))
    y = hint(x, ("pod", "data"), "model")
    assert y is x or bool((y == x).all())


def test_input_specs_shapes():
    cfg = get_config("llama3_2_1b")
    t = input_specs(cfg, "train_4k", num_clients=16, local_steps=1)
    assert t["batch"]["tokens"].shape == (16, 1, 16, 4096)
    p = input_specs(cfg, "prefill_32k")
    assert p["batch"]["tokens"].shape == (32, 32768)
    d = input_specs(cfg, "decode_32k")
    assert d["tokens"].shape == (128, 1)
    assert d["cache"]["layers"]["l0"]["k"].shape == (16, 128, 32768, 8, 64)


def test_input_specs_swa_cache_is_window_sized():
    cfg = get_config("h2o_danube_1_8b")
    d = input_specs(cfg, "long_500k")
    # SWA ring buffer: cache seq dim == window, not 524288
    assert d["cache"]["layers"]["l0"]["k"].shape[2] == cfg.sliding_window


def test_long_context_eligibility():
    ok, _ = shape_eligible(get_config("falcon_mamba_7b"), "long_500k")
    assert ok
    ok, why = shape_eligible(get_config("qwen2_7b"), "long_500k")
    assert not ok and "full-attention" in why
    ok, _ = shape_eligible(get_config("jamba_1_5_large_398b"), "long_500k")
    assert ok


def test_vlm_and_audio_specs_provide_frontend_embeddings():
    v = input_specs(get_config("qwen2_vl_7b"), "train_4k", num_clients=16)
    assert "patch_embeds" in v["batch"]
    assert v["batch"]["tokens"].shape[-1] == 4096 - 256
    a = input_specs(get_config("whisper_large_v3"), "train_4k", num_clients=16)
    assert a["batch"]["audio_embeds"].shape[-2:] == (1500, 1280)


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs import get_config
    from repro.core.safl import SAFLConfig
    from repro.core.sketch import SketchConfig
    from repro.core.adaptive import AdaConfig
    from repro.launch.train import (make_safl_train_step, make_serve_step,
        batch_pspecs, cache_pspecs, opt_pspecs, to_shardings, data_axes_of)
    from repro.launch.dryrun import abstract_params, abstract_opt_state
    from repro.models.sharding import param_pspecs, use_mesh
    from repro.models.model import cache_shapes

    from repro.launch.mesh import _mesh
    mesh = _mesh((2, 4), ("data", "model"))
    cfg = get_config("llama3_2_1b", smoke=True)
    safl = SAFLConfig(sketch=SketchConfig(kind="countsketch", ratio=0.01),
                      server=AdaConfig(name="amsgrad", lr=1e-3),
                      client_lr=0.01, local_steps=2)
    with use_mesh(mesh):
        pa = abstract_params(cfg)
        pspecs = param_pspecs(pa)
        p_sh = to_shardings(mesh, pspecs)
        step, _ = make_safl_train_step(cfg, safl, mesh)
        batch = {"tokens": jax.ShapeDtypeStruct((2, 2, 4, 64), jnp.int32)}
        o_abs = abstract_opt_state(safl.server, pa)
        jit = jax.jit(step, in_shardings=(
            p_sh, to_shardings(mesh, opt_pspecs(safl.server, pspecs)),
            to_shardings(mesh, batch_pspecs(batch, mesh)),
            NamedSharding(mesh, P())))
        c = jit.lower(pa, o_abs, batch,
                      jax.ShapeDtypeStruct((2,), jnp.uint32)).compile()
        assert c.cost_analysis() is not None
        # serve step
        serve = make_serve_step(cfg)
        daxes = data_axes_of(mesh)
        cshapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(tuple(s), cfg.dtype),
            cache_shapes(cfg, 8, 128), is_leaf=lambda x: isinstance(x, tuple))
        c_sh = to_shardings(mesh, cache_pspecs(cshapes, daxes))
        jit2 = jax.jit(serve, in_shardings=(
            p_sh, c_sh, NamedSharding(mesh, P(daxes, None)),
            NamedSharding(mesh, P())))
        c2 = jit2.lower(pa, cshapes, jax.ShapeDtypeStruct((8, 1), jnp.int32),
                        jax.ShapeDtypeStruct((), jnp.int32)).compile()
        assert c2.cost_analysis() is not None
    print("MINI_DRYRUN_OK")
""")


# no jax-version gate anymore: on 0.4.x (whose XLA hard-crashes on sharding
# hints inside a partial-manual region, IsManualSubgroup CHECK) the
# cross_device client deltas take the vmap fallback (DESIGN §9), so the
# distributed step compiles on both stacks
def test_mini_dryrun_8_devices():
    """Distributed SAFL train + serve lower AND compile on an 8-device host
    mesh (subprocess so the device-count flag never leaks into this test
    session)."""
    import os
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    if "JAX_PLATFORMS" in os.environ:  # keep the CPU pin; without it the
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]  # subprocess may
        # spend minutes probing an absent TPU backend before falling back
    r = subprocess.run([sys.executable, "-c", MINI_DRYRUN],
                       capture_output=True, text=True, timeout=900,
                       env=env, cwd="/root/repo")
    assert "MINI_DRYRUN_OK" in r.stdout, r.stderr[-3000:]


def test_hlo_costs_trip_weighting():
    from repro.launch.hlo_costs import analyze_hlo_text
    from jax import lax

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = lax.scan(body, x, None, length=12)
        return y

    comp = jax.jit(f).lower(jnp.ones((8, 8)), jnp.ones((8, 8))).compile()
    c = analyze_hlo_text(comp.as_text())
    assert c.flops == 12 * 2 * 8 * 8 * 8
