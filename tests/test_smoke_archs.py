"""Per-architecture smoke tests: a REDUCED same-family variant of every
assigned config runs one SAFL train round + one decode step on CPU,
asserting output shapes and no NaNs (deliverable f)."""

import functools

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.core.adaptive import AdaConfig
from repro.core.safl import SAFLConfig, init_safl, safl_round
from repro.core.sketch import SketchConfig
from repro.models import (count_params_analytic, decode_step, init_cache,
                          init_params, loss_fn)

SAFL = SAFLConfig(
    sketch=SketchConfig(kind="countsketch", ratio=0.05, min_b=16),
    server=AdaConfig(name="amsgrad", lr=1e-3),
    client_lr=0.02, local_steps=2)


def _batch_for(cfg, G=2, K=2, mb=2, S=16):
    key = jax.random.key(0)
    P = cfg.num_frontend_tokens if cfg.frontend == "vision" else 0
    batch = {"tokens": jax.random.randint(key, (G, K, mb, S), 0,
                                          cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            key, (G, K, mb, P, cfg.d_model), cfg.dtype) * 0.02
    if cfg.frontend == "audio":
        batch["audio_embeds"] = jax.random.normal(
            key, (G, K, mb, cfg.encoder_seq, cfg.d_model), cfg.dtype) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_round(arch):
    cfg = get_config(arch, smoke=True)
    assert count_params_analytic(cfg) < 50e6, "smoke variant too large"
    params = init_params(cfg, jax.random.key(0))
    opt = init_safl(SAFL, params)
    batch = _batch_for(cfg)
    loss = lambda p, b: loss_fn(cfg, p, b)
    p2, opt2, m = jax.jit(functools.partial(safl_round, SAFL, loss))(
        params, opt, batch, jax.random.key(1))
    assert jnp.isfinite(m["loss"]), (arch, m)
    # params changed and stayed finite
    moved = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert moved > 0
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(p2)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.key(0))
    B = 2
    cache = init_cache(cfg, B, 32)
    logits, cache2 = jax.jit(
        lambda p, c, t, i: decode_step(cfg, p, c, t, i))(
        params, cache, jnp.zeros((B, 1), jnp.int32), jnp.asarray(3, jnp.int32))
    assert logits.shape == (B, cfg.vocab_size), arch
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "falcon_mamba_7b": dict(num_layers=64, d_model=4096, vocab_size=65024,
                                ssm_state=16),
        "whisper_large_v3": dict(num_layers=32, d_model=1280, num_heads=20,
                                 num_kv_heads=20, d_ff=5120, vocab_size=51866),
        "jamba_1_5_large_398b": dict(num_layers=72, d_model=8192,
                                     num_heads=64, num_kv_heads=8,
                                     d_ff=24576, vocab_size=65536,
                                     num_experts=16, moe_top_k=2),
        "qwen2_vl_7b": dict(num_layers=28, d_model=3584, num_heads=28,
                            num_kv_heads=4, d_ff=18944, vocab_size=152064),
        "h2o_danube_1_8b": dict(num_layers=24, d_model=2560, num_heads=32,
                                num_kv_heads=8, d_ff=6912, vocab_size=32000),
        "llama3_2_1b": dict(num_layers=16, d_model=2048, num_heads=32,
                            num_kv_heads=8, d_ff=8192, vocab_size=128256),
        "qwen1_5_4b": dict(num_layers=40, d_model=2560, num_heads=20,
                           num_kv_heads=20, d_ff=6912, vocab_size=151936,
                           attn_bias=True),
        "deepseek_v3_671b": dict(num_layers=61, d_model=7168, num_heads=128,
                                 num_kv_heads=128, vocab_size=129280,
                                 num_experts=256, moe_top_k=8, moe_d_ff=2048),
        "qwen2_7b": dict(num_layers=28, d_model=3584, num_heads=28,
                         num_kv_heads=4, d_ff=18944, vocab_size=152064),
        "dbrx_132b": dict(num_layers=40, d_model=6144, num_heads=48,
                          num_kv_heads=8, d_ff=10752, vocab_size=100352,
                          num_experts=16, moe_top_k=4),
        "bert_100m": dict(num_layers=12, d_model=768),
        "vit_base_86m": dict(num_layers=12, d_model=768),
    }[arch]
    for k, v in expect.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_near_published():
    """Analytic parameter counts land near the published sizes."""
    targets = {
        "falcon_mamba_7b": (7.27e9, 0.10),
        "jamba_1_5_large_398b": (398e9, 0.05),
        "deepseek_v3_671b": (671e9, 0.02),
        "dbrx_132b": (132e9, 0.05),
        "llama3_2_1b": (1.24e9, 0.05),
        "qwen2_7b": (7.6e9, 0.05),
    }
    for arch, (target, tol) in targets.items():
        n = count_params_analytic(get_config(arch))
        assert abs(n - target) / target < tol, (arch, n, target)
