"""Hypothesis property-based tests on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional test dep (pip install -e .[test]); suite must still collect")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import sketch as S
from repro.core.adaptive import AdaConfig, apply_update, init_opt_state

KIND = st.sampled_from(["gaussian", "srht", "countsketch"])


@settings(max_examples=25, deadline=None)
@given(kind=KIND, n=st.integers(4, 400), seed=st.integers(0, 2**31 - 1),
       a=st.floats(-5, 5, allow_nan=False), b=st.floats(-5, 5, allow_nan=False))
def test_sketch_linearity_property(kind, n, seed, a, b):
    """Property 1 holds for every size/seed/coefficient combination."""
    cfg = S.SketchConfig(kind=kind, ratio=0.5, min_b=4)
    key = jax.random.key(seed)
    kv = jax.random.key(seed + 1)
    v = jax.random.normal(kv, (n,))
    w = jax.random.normal(jax.random.fold_in(kv, 1), (n,))
    lhs = S.sk_leaf(cfg, key, a * v + b * w)
    rhs = a * S.sk_leaf(cfg, key, v) + b * S.sk_leaf(cfg, key, w)
    scale = float(jnp.abs(lhs).max()) + 1.0
    np.testing.assert_allclose(np.array(lhs), np.array(rhs),
                               atol=5e-4 * scale)


@settings(max_examples=25, deadline=None)
@given(kind=KIND, n=st.integers(8, 300), seed=st.integers(0, 2**31 - 1))
def test_roundtrip_shape_and_finite(kind, n, seed):
    cfg = S.SketchConfig(kind=kind, ratio=0.3, min_b=4)
    key = jax.random.key(seed)
    v = jax.random.normal(jax.random.fold_in(key, 7), (n,))
    rt = S.desk_leaf(cfg, key, S.sk_leaf(cfg, key, v), n)
    assert rt.shape == (n,)
    assert bool(jnp.isfinite(rt).all())


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 2000), ratio=st.floats(0.001, 1.0))
def test_sketch_size_monotone_and_bounded(n, ratio):
    cfg = S.SketchConfig(kind="countsketch", ratio=ratio, min_b=2)
    b = S.leaf_sketch_size(n, cfg)
    assert 1 <= b <= n
    cfg2 = S.SketchConfig(kind="countsketch", ratio=min(1.0, ratio * 2),
                          min_b=2)
    assert S.leaf_sketch_size(n, cfg2) >= b


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       steps=st.integers(1, 8))
def test_amsgrad_vhat_never_decreases(seed, steps):
    """Alg. 2 invariant: v-hat is element-wise non-decreasing."""
    cfg = AdaConfig(name="amsgrad", lr=0.01)
    key = jax.random.key(seed)
    params = {"w": jax.random.normal(key, (16,))}
    state = init_opt_state(cfg, params)
    prev = np.zeros(16)
    for t in range(steps):
        u = {"w": jax.random.normal(jax.random.fold_in(key, t), (16,))}
        params, state = apply_update(cfg, state, params, u)
        vh = np.array(state["vhat"]["w"])
        assert (vh >= prev - 1e-12).all()
        prev = vh


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_update_descends_along_update_direction(seed):
    """ADA_OPT always moves against the (sign of the) update direction
    coordinate-wise (positive preconditioner)."""
    cfg = AdaConfig(name="amsgrad", lr=0.1)
    key = jax.random.key(seed)
    params = {"w": jax.random.normal(key, (8,))}
    u = {"w": jax.random.normal(jax.random.fold_in(key, 1), (8,))}
    p2, _ = apply_update(cfg, init_opt_state(cfg, params), params, u)
    dw = np.array(p2["w"] - params["w"])
    uw = np.array(u["w"])
    nz = np.abs(uw) > 1e-6
    assert (np.sign(dw[nz]) == -np.sign(uw[nz])).all()


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 9))
def test_fwht_energy_preservation(n):
    """Parseval: ||Hx||^2 = n ||x||^2 for the unnormalized transform."""
    size = 1 << n
    x = jax.random.normal(jax.random.key(n), (size,))
    y = S.fwht(x)
    np.testing.assert_allclose(float(y @ y), size * float(x @ x), rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), g=st.integers(1, 5))
def test_sketch_mergeability(seed, g):
    """Mean of client sketches == sketch of client mean (exact, any G)."""
    cfg = S.SketchConfig(kind="countsketch", ratio=0.5, min_b=4)
    key = jax.random.key(seed)
    vs = [jax.random.normal(jax.random.fold_in(key, i), (64,))
          for i in range(g)]
    sks = [S.sk_leaf(cfg, key, v) for v in vs]
    mean_sk = sum(np.array(s) for s in sks) / g
    sk_mean = np.array(S.sk_leaf(cfg, key, sum(vs) / g))
    np.testing.assert_allclose(mean_sk, sk_mean, atol=1e-4)


# ---------------------------------------------------------------------------
# participation policies (ISSUE 4 satellite): fed/participation.py invariants
# ---------------------------------------------------------------------------

from repro.fed.participation import (ImportanceParticipation,  # noqa: E402
                                     UniformParticipation, round_variates)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 24), frac=st.floats(1e-3, 1.0),
       seed=st.integers(0, 2**31 - 1), t=st.integers(0, 10_000))
def test_participation_cohort_bounds_and_no_replacement(n, frac, seed, t):
    """For ANY frac in (0, 1]: the mask is strictly 0/1 (no client counted
    twice -- sampling without replacement), the cohort size is within
    [1, N], and the mask sums to exactly the declared cohort size."""
    pol = UniformParticipation(n, frac=frac, seed=seed)
    m = np.asarray(pol.mask(jnp.asarray(t, jnp.int32)))
    assert m.shape == (n,)
    assert set(np.unique(m)).issubset({np.float32(0.0), np.float32(1.0)})
    assert 1 <= pol.cohort_size <= n
    assert int(m.sum()) == pol.cohort_size


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 16), extra=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1), t=st.integers(0, 10_000))
def test_participation_pure_in_round_client_seed(n, extra, seed, t):
    """The per-client variate stream is a pure function of
    (round, client, seed): a fresh policy instance reproduces the mask
    bitwise, and client c's variate does not change when clients are added
    (the N-independence the device data sampler also guarantees)."""
    tt = jnp.asarray(t, jnp.int32)
    pol = UniformParticipation(n, frac=0.5, seed=seed)
    m1 = np.asarray(pol.mask(tt))
    m2 = np.asarray(UniformParticipation(n, frac=0.5, seed=seed).mask(tt))
    np.testing.assert_array_equal(m1, m2)
    u_small = np.asarray(round_variates(n, seed, tt))
    u_large = np.asarray(round_variates(n + extra, seed, tt))
    np.testing.assert_array_equal(u_small, u_large[:n])
    if n >= 4:
        # different rounds draw different variates (collision probability
        # across 4+ f32 uniforms is negligible)
        u_next = np.asarray(round_variates(n, seed, tt + 1))
        assert not np.array_equal(u_small, u_next)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 12), seed=st.integers(0, 2**31 - 1),
       t=st.integers(0, 10_000),
       raw=st.lists(st.floats(0.05, 1.0), min_size=2, max_size=12))
def test_importance_mask_invariants(n, seed, t, raw):
    """ImportanceParticipation: exactly m clients sampled (no replacement),
    sampled weights equal 1/(N p_c), static denominator m, and the mask is
    reproducible from a fresh policy instance."""
    raw = (raw * n)[:n]
    probs = tuple(float(p) / sum(raw) for p in raw)
    # renormalize the tail element so the tuple sums to 1 within 1e-6
    probs = probs[:-1] + (1.0 - sum(probs[:-1]),)
    # stay inside the policy's validity regime m * max(p) <= 1
    m = max(1, min(n // 2, int(1.0 / max(probs))))
    pol = ImportanceParticipation(n, probs=probs, frac=m / n, seed=seed)
    assert pol.cohort_size == m
    tt = jnp.asarray(t, jnp.int32)
    m = pol.mask(tt)
    w = np.asarray(m["w"])
    sel = w > 0
    assert int(sel.sum()) == pol.cohort_size == m["n"]
    assert m["den"] == float(pol.cohort_size)
    np.testing.assert_allclose(
        w[sel], (1.0 / (n * np.asarray(probs, np.float64)))[sel], rtol=1e-5)
    m2 = ImportanceParticipation(n, probs=probs, frac=0.5,
                                 seed=seed).mask(tt)
    np.testing.assert_array_equal(w, np.asarray(m2["w"]))


# ---------------------------------------------------------------------------
# async staleness buffer (ISSUE 5): the shared arrival-schedule invariants
# ---------------------------------------------------------------------------

from repro.fed.async_buffer import AsyncConfig, arrival_weight  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 12), max_delay=st.integers(0, 4),
       delay=st.sampled_from(["zero", "stagger", "uniform"]),
       alpha=st.floats(0.0, 2.0, allow_nan=False),
       seed=st.integers(0, 2**31 - 1), g=st.integers(0, 10_000))
def test_arrival_weight_every_payload_pops_exactly_once(n, max_delay, delay,
                                                        alpha, seed, g):
    """For ANY policy/seed/generation: delays land in [0, D), so summed over
    all pop delays every client's payload arrives EXACTLY once (no payload
    lost before its ring slot is recycled, none double-counted), each
    nonzero weight is exactly the FedBuff discount (1+d)^-alpha, and the
    schedule is reproducible (pure in (g, d, seed)) -- the contract both
    the single-host and the mesh ring buffers pop against."""
    acfg = AsyncConfig(max_delay=max_delay, delay=delay,
                       staleness_alpha=alpha, seed=seed)
    gg = jnp.asarray(g, jnp.int32)
    total = np.zeros((n,))
    for d in range(acfg.buffer_rounds):
        w = np.asarray(arrival_weight(acfg, gg, d, n))
        w2 = np.asarray(arrival_weight(acfg, gg, d, n))
        np.testing.assert_array_equal(w, w2)
        disc = np.float32((1.0 + d) ** -alpha)   # the f32 the buffer applies
        arrived = w > 0
        np.testing.assert_array_equal(w[arrived],
                                      np.full(int(arrived.sum()), disc))
        total += arrived
    np.testing.assert_array_equal(total, np.ones((n,)))
