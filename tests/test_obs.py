"""In-graph telemetry, streamed metric shards, manifests (DESIGN.md §11).

Pins the ISSUE 8 contracts on the single-host scan driver:

  * telemetry OFF is the status quo: a ``telemetry=None`` round emits no
    probe keys, and attaching a ``stream=`` writer is pure host-side I/O --
    params/state/history stay bitwise identical to the unstreamed run;
  * telemetry ON defines its own program family, pinned WITHIN the family:
    streamed shard rows equal the in-memory history value-for-value, and a
    chunk-split run's concatenated rows equal the single-dispatch run's
    (shard boundaries are an I/O artifact, not a numeric one);
  * probe sanity across sketch families: the desketch residual is a
    relative quantity in [0, ~1], the effective cohort counts the
    aggregation mask, the uncompressed FedOPT reference reads residual 0,
    and SACFL's clip_frac hits its {0, 1} extremes under extreme taus;
  * the rollback supervisor's recovery events land in the same event log
    with the documented schema, and ``tools/check_telemetry.py`` accepts
    a real run directory (duplicate rounds across shards included) while
    rejecting schema violations.
"""

import functools
import glob
import json
import os
import sys

import jax
import numpy as np
import pytest

from repro.core.adaptive import AdaConfig
from repro.core.clipped import ClippedSAFLConfig, clipped_safl_round
from repro.core.packed import make_packing_plan
from repro.core.safl import SAFLConfig, fedopt_round, init_safl, safl_round
from repro.core.sketch import SketchConfig
from repro.launch.driver import HISTORY_KEYS, run_scan
from repro.launch.supervisor import SupervisorConfig, run_supervised
from repro.obs import (PROBE_KEYS, ShardWriter, Telemetry, format_summary,
                       span_stats, write_manifest)
from test_faults import _TransientFaults, _row
from test_fed import (G, _LinearSampler, _linear_loss, _params0, _safl_setup,
                      _SK)
from repro.fed import NAN

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import check_telemetry  # noqa: E402  (tools/ is not a package)

TEL = Telemetry()


def _run(round_fn, fresh, *, rounds=4, chunk_size=0, **kw):
    p0, s0 = fresh()
    return run_scan(round_fn, _LinearSampler(), p0, s0, rounds=rounds,
                    key=jax.random.key(0), chunk_size=chunk_size, **kw)


def _eq(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _rows(run_dir):
    rows = []
    for path in sorted(glob.glob(os.path.join(run_dir, "metrics-*.jsonl"))):
        with open(path) as f:
            rows += [json.loads(ln) for ln in f if ln.strip()]
    return rows


def _events(run_dir, kind=None):
    path = os.path.join(run_dir, "events.jsonl")
    with open(path) as f:
        evs = [json.loads(ln) for ln in f if ln.strip()]
    return [e for e in evs if kind is None or e["kind"] == kind]


# ---------------------------------------------------------------------------
# disabled path: no probe keys, and streaming is host-side I/O only
# ---------------------------------------------------------------------------

def test_telemetry_off_emits_no_probe_keys():
    _, _, round_fn, fresh = _safl_setup()
    _, _, h = _run(round_fn, fresh)
    assert set(h) == {"loss"}
    assert not set(h) & set(PROBE_KEYS)


def test_stream_is_host_side_io_only(tmp_path):
    """stream= with telemetry off: the compiled program is untouched, so
    params/state are bitwise the unstreamed run's and the shard rows carry
    exactly the unstreamed history's values."""
    _, _, round_fn, fresh = _safl_setup()
    pA, sA, hA = _run(round_fn, fresh, chunk_size=2, bits_per_round=64)
    stream = ShardWriter(str(tmp_path / "run"))
    pB, sB, hB = _run(round_fn, fresh, chunk_size=2, bits_per_round=64,
                      stream=stream)
    _eq((pA, sA), (pB, sB))
    assert hB == {}                       # shards are the record
    rows = _rows(str(tmp_path / "run"))
    assert [r["t"] for r in rows] == list(range(4))
    np.testing.assert_array_equal([r["loss"] for r in rows], hA["loss"])
    np.testing.assert_array_equal([r["uplink_bits"] for r in rows],
                                  hA["uplink_bits"])


# ---------------------------------------------------------------------------
# enabled path: pinned within the telemetry program family
# ---------------------------------------------------------------------------

def test_streamed_rows_match_in_memory_history(tmp_path):
    """Same program (telemetry on both sides): streamed JSONL rows ==
    in-memory stacked history, key for key, round for round."""
    _, _, round_fn, fresh = _safl_setup()
    rf = functools.partial(round_fn, telemetry=TEL)
    pA, _, hA = _run(rf, fresh, chunk_size=2)
    stream = ShardWriter(str(tmp_path / "run"))
    pB, _, hB = _run(rf, fresh, chunk_size=2, stream=stream)
    _eq(pA, pB)
    assert hB == {}
    rows = _rows(str(tmp_path / "run"))
    assert len(rows) == 4
    for i, row in enumerate(rows):
        assert row["kind"] == "metrics" and row["t"] == i
        assert set(row) - {"kind", "t"} == set(hA)
        for k in hA:
            assert row[k] == float(hA[k][i])


def test_chunk_split_shard_invariance(tmp_path):
    """Shard boundaries are an I/O artifact: a chunk_size=2 run's
    concatenated rows equal the single-dispatch run's, bit for bit."""
    _, _, round_fn, fresh = _safl_setup()
    rf = functools.partial(round_fn, telemetry=TEL)
    s1 = ShardWriter(str(tmp_path / "one"))
    p1, _, _ = _run(rf, fresh, stream=s1)                  # one dispatch
    s2 = ShardWriter(str(tmp_path / "split"))
    p2, _, _ = _run(rf, fresh, chunk_size=2, stream=s2)
    _eq(p1, p2)
    assert s1._shard == 1 and s2._shard == 2
    assert _rows(str(tmp_path / "one")) == _rows(str(tmp_path / "split"))
    # spans: first dispatch of each chunk length is flagged compile=True
    spans = _events(str(tmp_path / "split"), "span")
    assert [s["compile"] for s in spans] == [True, False]
    assert [(s["t0"], s["t1"]) for s in spans] == [(0, 2), (2, 4)]


# ---------------------------------------------------------------------------
# probe sanity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["countsketch", "srht", "gaussian"])
def test_probe_sanity_across_sketch_families(kind):
    """Residual is a RELATIVE desketch error (O(1), not norm-scaled); the
    cohort probe counts the full unmasked cohort; moment norms track the
    amsgrad server state; everything is finite and (rounds,)-shaped."""
    sk = SketchConfig(kind=kind, ratio=0.25, min_b=8)
    cfg = SAFLConfig(sketch=sk, server=AdaConfig(name="amsgrad", lr=0.05),
                     client_lr=0.05, local_steps=2)
    plan = make_packing_plan(sk, _params0())
    rf = functools.partial(safl_round, cfg, _linear_loss, plan=plan,
                           telemetry=TEL)
    fresh = lambda: (_params0(), init_safl(cfg, _params0()))
    _, _, h = _run(rf, fresh)
    expect = {"loss", "delta_norm", "update_norm", "residual", "m_norm",
              "v_norm", "vhat_norm", "cohort"}
    assert set(h) == expect
    assert set(h) <= set(HISTORY_KEYS)
    for k in expect:
        assert h[k].shape == (4,) and np.isfinite(h[k]).all(), k
    assert (h["delta_norm"] > 0).all()
    # unbiased desketch: relative error concentrates around sqrt(d/b) = 2
    # at ratio 0.25 -- O(1) in the RELATIVE sense, never norm-scaled
    assert (h["residual"] >= 0).all() and (h["residual"] < 4.0).all()
    np.testing.assert_array_equal(h["cohort"], np.full(4, float(G)))
    assert (h["m_norm"] > 0).all() and (h["vhat_norm"] > 0).all()


def test_fedopt_reference_residual_is_zero():
    """The uncompressed reference applies Δ̄ itself: desk(sk(Δ̄)) == Δ̄ and
    the residual probe reads exactly 0 -- the sketch-noise baseline."""
    cfg = SAFLConfig(sketch=_SK, server=AdaConfig(name="amsgrad", lr=0.05),
                     client_lr=0.05, local_steps=2)
    rf = functools.partial(fedopt_round, cfg, _linear_loss, telemetry=TEL)
    fresh = lambda: (_params0(), init_safl(cfg, _params0()))
    _, _, h = _run(rf, fresh)
    np.testing.assert_array_equal(h["residual"], np.zeros(4))
    np.testing.assert_array_equal(h["update_norm"], h["delta_norm"])


@pytest.mark.parametrize("tau,frac", [(1e-6, 1.0), (1e6, 0.0)])
def test_clip_frac_extremes(tau, frac):
    """SACFL's clip_frac probe: a vanishing tau clips every client, a huge
    tau clips none."""
    base = SAFLConfig(sketch=_SK, server=AdaConfig(name="amsgrad", lr=0.05),
                      client_lr=0.05, local_steps=2)
    cfg = ClippedSAFLConfig(base=base, clip_tau=tau)
    plan = make_packing_plan(_SK, _params0())
    rf = functools.partial(clipped_safl_round, cfg, _linear_loss, plan=plan,
                           telemetry=TEL)
    fresh = lambda: (_params0(), init_safl(base, _params0()))
    _, _, h = _run(rf, fresh)
    np.testing.assert_array_equal(h["clip_frac"], np.full(4, frac))


# ---------------------------------------------------------------------------
# supervisor recovery events + the schema validator
# ---------------------------------------------------------------------------

def test_supervisor_recovery_events_in_stream(tmp_path):
    """A supervised run with a transient fault streams its rollback as a
    structured recovery event next to the spans, re-emits the retried span
    in new shards (duplicate t, last-wins), and the whole directory passes
    tools/check_telemetry.py."""
    run_dir = str(tmp_path / "sup")
    _, _, round_fn, fresh = _safl_setup()
    key = jax.random.key(0)
    faults = _TransientFaults(key, _row(NAN))   # fires on rounds [4, 6)
    stream = ShardWriter(run_dir)
    write_manifest(run_dir, run="test", sketch=_SK, guard_pins=None)
    sampler = _LinearSampler()

    def launch(p, s, *, key, start_round, on_chunk):
        return run_scan(round_fn, sampler, p, s, rounds=8, key=key,
                        chunk_size=2, start_round=start_round,
                        on_chunk=on_chunk, faults=faults, stream=stream)

    p0, s0 = fresh()
    p, s, hist, log = run_supervised(
        launch, p0, s0, rounds=8, key=key,
        config=SupervisorConfig(max_retries=3), stream=stream)
    assert hist == {}                     # shards are the record
    assert len(log) == 1

    recs = _events(run_dir, "recovery")
    assert len(recs) == 1
    for field in check_telemetry.RECOVERY_FIELDS + ("rekey",):
        assert field in recs[0], field
    assert recs[0]["retry"] == 1
    assert recs[0]["t_resume"] == 4
    assert recs[0]["depth"] == recs[0]["t_fault"] - recs[0]["t_resume"] >= 0

    # the faulted chunk's shard was already written (NaN rows), then the
    # retried span re-emitted rounds 4..8 -> duplicate t across shards,
    # 8 distinct rounds, and the validator accepts all of it
    rows = _rows(run_dir)
    ts = [r["t"] for r in rows]
    assert len(ts) > 8 and sorted(set(ts)) == list(range(8))
    assert check_telemetry.check(run_dir, rounds=8) == []
    assert stream.summary()["recoveries"] == 1


def test_check_telemetry_rejects_violations(tmp_path):
    bad = str(tmp_path / "bad")
    os.makedirs(bad)
    with open(os.path.join(bad, "metrics-00000.jsonl"), "w") as f:
        f.write(json.dumps({"kind": "metrics", "t": 0, "loss": 1.0}) + "\n")
        f.write(json.dumps({"kind": "metrics", "t": 2, "loss": 1.0,
                            "bogus_key": 3.0}) + "\n")
    with open(os.path.join(bad, "events.jsonl"), "w") as f:
        f.write(json.dumps({"kind": "span", "t0": 0}) + "\n")
        f.write(json.dumps({"kind": "mystery"}) + "\n")
    errs = check_telemetry.check(bad, rounds=4)
    text = "\n".join(errs)
    assert "manifest.json missing" in text
    assert "not consecutive" in text
    assert "bogus_key" in text
    assert "missing 't1'" in text
    assert "unknown kind" in text
    assert "distinct metric rounds 2 != expected 4" in text


def test_check_telemetry_accepts_clean_run(tmp_path):
    run_dir = str(tmp_path / "ok")
    _, _, round_fn, fresh = _safl_setup()
    rf = functools.partial(round_fn, telemetry=TEL)
    stream = ShardWriter(run_dir)
    write_manifest(run_dir, run="test", guard_pins=None)
    _run(rf, fresh, chunk_size=2, stream=stream)
    assert check_telemetry.check(run_dir, rounds=4) == []
    assert check_telemetry.main([run_dir, "--rounds", "4"]) == 0


# ---------------------------------------------------------------------------
# writer aggregates, manifest, span stats
# ---------------------------------------------------------------------------

def test_shard_writer_aggregates_and_summary(tmp_path):
    w = ShardWriter(str(tmp_path / "w"))
    w.write_chunk(0, {"loss": np.asarray([4.0, 2.0]),
                      "residual": np.asarray([0.5, 0.3])})
    w.write_chunk(2, {"loss": np.asarray([1.0]),
                      "residual": np.asarray([0.1])})
    s = w.summary()
    assert s["rounds"] == 3 and s["shards"] == 2
    assert s["final_loss"] == 1.0
    np.testing.assert_allclose(s["mean_residual"], 0.3)
    assert s["recoveries"] == 0 and s["total_rejected"] is None
    line = format_summary(s)
    assert "rounds=3" in line and "final_loss=1.0000" in line


def test_manifest_schema(tmp_path):
    path = write_manifest(str(tmp_path / "m"), run="unit",
                          sketch=_SK, config={"rounds": 4},
                          topology="single-host", guard_pins=None)
    with open(path) as f:
        man = json.load(f)
    from repro.obs import REQUIRED_KEYS
    for k in REQUIRED_KEYS:
        assert k in man, k
    assert man["sketch"]["kind"] == "countsketch"
    assert man["config"]["rounds"] == 4
    assert man["topology"] == "single-host"


def test_span_stats():
    assert span_stats([]) == {}
    st = span_stats([1e-3, 2e-3, 3e-3])
    assert st["p50_us"] == pytest.approx(2000.0)
    assert st["p50_us"] <= st["p95_us"]
