"""Per-kernel Pallas (interpret=True) vs pure-jnp/numpy oracle sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.countsketch import (countsketch_clients_pallas,
                                       countsketch_pallas)
from repro.kernels.fwht import fwht_pallas, fwht_rows_pallas
from repro.kernels.gaussian_sketch import (gaussian_desk_pallas,
                                           gaussian_sk_pallas)


@pytest.mark.parametrize("n", [17, 1000, 1024, 5000])
@pytest.mark.parametrize("b", [8, 128, 300])
def test_countsketch_shapes(n, b):
    rng = np.random.RandomState(n + b)
    x = rng.randn(n).astype(np.float32)
    h = rng.randint(0, b, n).astype(np.int32)
    got = countsketch_pallas(jnp.array(x), jnp.array(h), b)
    want = ref.countsketch_ref(jnp.array(x), jnp.array(h), b)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_countsketch_dtypes(dtype):
    rng = np.random.RandomState(0)
    x = rng.randn(333).astype(dtype)
    h = rng.randint(0, 64, 333).astype(np.int32)
    got = countsketch_pallas(jnp.asarray(x, jnp.float32), jnp.array(h), 64)
    want = ref.countsketch_ref(jnp.asarray(x, jnp.float32), jnp.array(h), 64)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-4)


@pytest.mark.parametrize("b", [2049, 4096])
def test_countsketch_large_b_split_by_grid(b):
    """b beyond one VMEM block is split on the b-block grid axis (the old
    wrapper claimed-but-didn't; now the kernel handles any b)."""
    rng = np.random.RandomState(b)
    x = rng.randn(3000).astype(np.float32)
    h = rng.randint(0, b, 3000).astype(np.int32)
    got = ops.countsketch(jnp.array(x), jnp.array(h), b)
    want = ref.countsketch_ref(jnp.array(x), jnp.array(h), b)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("g,n,b", [(1, 100, 16), (5, 2000, 64),
                                   (9, 1500, 3000)])
def test_countsketch_clients_batched(g, n, b):
    """One launch for all G client rows == per-row reference."""
    rng = np.random.RandomState(g + n)
    x = rng.randn(g, n).astype(np.float32)
    h = rng.randint(0, b, n).astype(np.int32)
    got = countsketch_clients_pallas(jnp.array(x), jnp.array(h), b)
    want = np.stack([np.array(ref.countsketch_ref(jnp.array(x[i]),
                                                  jnp.array(h), b))
                     for i in range(g)])
    np.testing.assert_allclose(np.array(got), want, rtol=1e-4, atol=1e-4)


def test_countsketch_clients_jit_wrapper():
    x = jnp.ones((3, 100))
    h = jnp.zeros((100,), jnp.int32)
    out = ops.countsketch_clients(x, h, 4)
    assert out.shape == (3, 4)
    np.testing.assert_allclose(np.array(out[:, 0]), 100.0)


@pytest.mark.parametrize("shape", [(1, 8), (3, 64), (20, 512), (9, 4096)])
def test_fwht_rows(shape):
    x = np.random.RandomState(1).randn(*shape).astype(np.float32)
    got = fwht_rows_pallas(jnp.array(x))
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(np.array(got), want, rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("n", [64, 4096, 8192, 32768])
def test_fwht_1d_including_kronecker_path(n):
    x = np.random.RandomState(2).randn(n).astype(np.float32)
    got = fwht_pallas(jnp.array(x))
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(np.array(got), want, rtol=1e-3, atol=0.2)


def test_fwht_rows_wrapper_long_rows():
    """ops.fwht_rows falls back to the per-row Kronecker path for C > MAX_C."""
    x = np.random.RandomState(4).randn(2, 8192).astype(np.float32)
    got = ops.fwht_rows(jnp.array(x))
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(np.array(got), want, rtol=1e-3, atol=0.2)


def test_fwht_involution():
    """H (H x) = n x."""
    n = 1024
    x = np.random.RandomState(3).randn(n).astype(np.float32)
    y = fwht_pallas(fwht_pallas(jnp.array(x)))
    np.testing.assert_allclose(np.array(y) / n, x, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,b", [(100, 16), (513, 64), (2000, 128)])
def test_gaussian_sk_matches_ref(n, b):
    x = np.random.RandomState(4).randn(n).astype(np.float32)
    seed = jnp.array(11, jnp.uint32)
    got = gaussian_sk_pallas(seed, jnp.array(x), b)
    want = ref.gaussian_sk_ref(11, x, b)
    np.testing.assert_allclose(np.array(got), want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,b", [(100, 16), (1500, 128)])
def test_gaussian_desk_matches_ref(n, b):
    s = np.random.RandomState(5).randn(b).astype(np.float32)
    seed = jnp.array(11, jnp.uint32)
    got = gaussian_desk_pallas(seed, jnp.array(s), n)
    want = ref.gaussian_desk_ref(11, s, n)
    np.testing.assert_allclose(np.array(got), want, rtol=1e-3, atol=1e-3)


def test_gaussian_adjointness():
    """<sk(v), s> == <v, desk(s)> iff sk/desk regenerate identical R."""
    n, b = 900, 64
    rng = np.random.RandomState(6)
    v = rng.randn(n).astype(np.float32)
    s = rng.randn(b).astype(np.float32)
    seed = jnp.array(42, jnp.uint32)
    lhs = float(np.array(gaussian_sk_pallas(seed, jnp.array(v), b)) @ s)
    rhs = float(v @ np.array(gaussian_desk_pallas(seed, jnp.array(s), n)))
    assert abs(lhs - rhs) < 1e-2 * max(1.0, abs(lhs))


def test_gaussian_tile_statistics():
    """In-kernel PRNG produces (approximately) standard normals."""
    t = ref.gaussian_tile_ref(7, 0, 512, 128)
    assert abs(t.mean()) < 0.02
    assert abs(t.std() - 1.0) < 0.02


def test_ops_wrappers_jit():
    x = jnp.arange(256.0)
    h = jnp.zeros((256,), jnp.int32)
    assert float(ops.countsketch(x, h, 8)[0]) == float(x.sum())
    y = ops.fwht(jnp.ones((64,)))
    assert float(y[0]) == 64.0 and float(jnp.abs(y[1:]).max()) == 0.0
