"""Quantized payload codec (repro.fed.codec, DESIGN.md §13).

Pins the ISSUE 10 contracts:

  * quantizer properties: stochastic rounding is unbiased (int8 and
    1-bit), per-element round-trip error is bounded by the row scale,
    all-zero rows decode to exactly 0 (hypothesis twin below);
  * ``codec=None`` routes at Python level -- BITWISE vs the codec-free
    trajectories for safl, clipped safl, and the async buffer under
    run_scan (pin class 1, DESIGN appendix "Pinning methodology");
  * error-feedback memory: unsampled clients FREEZE their rows (they
    computed nothing), sampled clients accumulate the residual -- the
    codec twin of the PR-3 topk_ef test in test_fed.py;
  * streamed (``microbatch=``) vs materialized codec rounds agree to
    float tolerance, including the EF memory (same global-index RNG);
  * ``uplink_bits`` under a codec is the MEASURED wire size
    ``(b_total*bits + 32) * n_transmitting``, not the float32 fiction;
  * rejection matrix: fedopt has no payload to encode; codec +
    telemetry is refused (EF wraps the opt state the probes read).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import AdaConfig
from repro.core.clipped import ClippedSAFLConfig, clipped_safl_round
from repro.core.packed import make_packing_plan
from repro.core.safl import SAFLConfig, fedopt_round, init_safl, safl_round
from repro.core.sketch import SketchConfig
from repro.fed import (AsyncConfig, CodecConfig, encode_decode,
                       init_async_state, init_codec_state,
                       make_async_round, measured_uplink_bits)
from repro.launch.driver import run_scan
from repro.obs import Telemetry

G = 4


class _LinearSampler:
    """Minimal driver-protocol sampler over a linear regression task."""

    def __init__(self, clients=G, local_steps=2, mb=4):
        self.shape = (clients, local_steps, mb, 16)
        self.W = np.asarray(jax.random.normal(jax.random.key(1), (16, 4)))

    def init_state(self):
        return {"W": jnp.asarray(self.W, jnp.float32)}

    def sample(self, state, t):
        x = jax.random.normal(jax.random.fold_in(jax.random.key(11), t),
                              self.shape)
        return state, {"x": x, "y": x @ state["W"]}


def _linear_loss(params, batch):
    return jnp.mean((batch["x"] @ params["W"] - batch["y"]) ** 2)


def _params0():
    return {"W": jnp.zeros((16, 4))}


_SK = SketchConfig(kind="countsketch", ratio=0.25, min_b=8)


def _safl_setup(clip=False):
    base = SAFLConfig(sketch=_SK, server=AdaConfig(name="amsgrad", lr=0.05),
                      client_lr=0.05, local_steps=2)
    plan = make_packing_plan(_SK, _params0())
    if clip:
        cfg = ClippedSAFLConfig(base=base, clip_tau=0.5)
        round_fn = functools.partial(clipped_safl_round, cfg, _linear_loss,
                                     plan=plan)
    else:
        cfg = base
        round_fn = functools.partial(safl_round, cfg, _linear_loss, plan=plan)
    fresh = lambda: (_params0(), init_safl(base, _params0()))
    return cfg, plan, round_fn, fresh


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _round_batch(t=0):
    smp = _LinearSampler()
    _, batch = smp.sample(smp.init_state(), jnp.asarray(t, jnp.int32))
    return batch


# ---------------------------------------------------------------------------
# quantizer properties
# ---------------------------------------------------------------------------

_ROWS = jax.random.normal(jax.random.key(3), (G, 24)) * jnp.asarray(
    [[1.0], [10.0], [0.01], [3.0]])          # heterogeneous row scales


@pytest.mark.parametrize("bits", [8, 1])
def test_roundtrip_error_bounded_by_row_scale(bits):
    """Per-element |decode - x| <= one quantization step: s = max|row|/127
    (int8, floor+u moves at most one step) resp. 2*max|row| (1-bit)."""
    codec = CodecConfig(bits=bits, error_feedback=False)
    dec, ef = encode_decode(codec, jax.random.key(0), _ROWS)
    assert ef is None
    assert bool(jnp.isfinite(dec).all())
    s = jnp.max(jnp.abs(_ROWS), axis=1, keepdims=True)
    step = s / 127.0 if bits == 8 else 2.0 * s
    assert bool((jnp.abs(dec - _ROWS) <= step * (1 + 1e-5)).all())
    if bits == 1:
        # decoded values are exactly +-s per row
        np.testing.assert_allclose(np.abs(np.asarray(dec)),
                                   np.asarray(s) * np.ones_like(dec),
                                   rtol=1e-6)


@pytest.mark.parametrize("bits", [8, 1])
def test_stochastic_rounding_is_unbiased(bits):
    """E[decode] == x over the rounding stream: the mean over many round
    keys converges to the input at the Monte-Carlo rate."""
    codec = CodecConfig(bits=bits, error_feedback=False)
    keys = jax.random.split(jax.random.key(7), 2000)
    dec = jax.vmap(lambda k: encode_decode(codec, k, _ROWS)[0])(keys)
    err = jnp.abs(jnp.mean(dec, axis=0) - _ROWS)
    s = jnp.max(jnp.abs(_ROWS), axis=1, keepdims=True)
    # std-error ~ s/(2*sqrt(N)) for int8, ~ s/sqrt(N) for 1-bit; allow 6x
    tol = (0.5 if bits == 8 else 1.0) * 6.0 / np.sqrt(2000)
    assert bool((err <= tol * s).all()), float(jnp.max(err / s))


def test_zero_rows_decode_exact_zero():
    rows = jnp.zeros((3, 16))
    for bits in (8, 1):
        dec, _ = encode_decode(CodecConfig(bits=bits, error_feedback=False),
                               jax.random.key(0), rows)
        np.testing.assert_array_equal(np.asarray(dec), 0.0)


def test_error_feedback_is_the_quantization_residual():
    codec = CodecConfig(bits=8)
    ef0 = init_codec_state(codec, G, _ROWS.shape[1])
    np.testing.assert_array_equal(np.asarray(ef0), 0.0)
    dec, ef1 = encode_decode(codec, jax.random.key(0), _ROWS, ef_rows=ef0)
    np.testing.assert_allclose(np.asarray(dec + ef1), np.asarray(_ROWS),
                               rtol=1e-5, atol=1e-6)


def test_hypothesis_roundtrip_properties():
    pytest.importorskip("hypothesis", reason="optional test dep (pip "
                        "install -e .[test]); suite must still collect")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.floats(min_value=-1e4, max_value=1e4, width=32),
                    min_size=1, max_size=32),
           st.sampled_from([8, 1]),
           st.integers(min_value=0, max_value=2**31 - 1))
    def prop(vals, bits, seed):
        rows = jnp.asarray(vals, jnp.float32)[None, :]
        codec = CodecConfig(bits=bits, error_feedback=False)
        dec, _ = encode_decode(codec, jax.random.key(seed), rows)
        assert bool(jnp.isfinite(dec).all())
        s = jnp.max(jnp.abs(rows))
        step = s / 127.0 if bits == 8 else 2.0 * s
        assert bool((jnp.abs(dec - rows) <= step * (1 + 1e-5) + 1e-30).all())

    prop()


# ---------------------------------------------------------------------------
# codec=None is bitwise (pin class 1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["safl", "clipped"])
def test_codec_none_is_bitwise_under_run_scan(algo):
    _, _, round_fn, fresh = _safl_setup(clip=algo == "clipped")
    key = jax.random.key(5)
    p1, s1, h1 = run_scan(round_fn, _LinearSampler(), *fresh(), rounds=4,
                          key=key)
    p2, s2, h2 = run_scan(functools.partial(round_fn, codec=None),
                          _LinearSampler(), *fresh(), rounds=4, key=key)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(s1, s2)


def test_codec_none_async_buffer_is_bitwise():
    cfg, plan, _, fresh = _safl_setup()
    acfg = AsyncConfig(max_delay=2, delay="uniform")
    key = jax.random.key(6)

    def run(codec):
        rf = make_async_round(cfg, _linear_loss, acfg, plan, codec=codec)
        p = _params0()
        s = init_async_state(cfg, acfg, p, plan, G, codec=codec)
        return run_scan(rf, _LinearSampler(), p, s, rounds=4, key=key,
                        buffer=True)

    p1, s1, h1 = run(None)
    p2, s2, h2 = run(None)   # determinism sanity
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    rf_plain = make_async_round(cfg, _linear_loss, acfg, plan)
    p3, s3, h3 = run_scan(rf_plain, _LinearSampler(), _params0(),
                          init_async_state(cfg, acfg, _params0(), plan, G),
                          rounds=4, key=key, buffer=True)
    np.testing.assert_array_equal(h1["loss"], h3["loss"])
    _assert_trees_equal(p1, p3)
    _assert_trees_equal(s1, s3)


# ---------------------------------------------------------------------------
# error-feedback semantics in the round
# ---------------------------------------------------------------------------

def test_ef_memory_freezes_unsampled_clients():
    """Codec twin of test_fed.py's topk_ef freeze pin: out-of-cohort
    clients keep their EF rows untouched, sampled clients accumulate."""
    cfg, plan, _, fresh = _safl_setup()
    params, opt = fresh()
    codec = CodecConfig(bits=8)
    wrapped = {"opt": opt, "ef": init_codec_state(codec, G, plan.b_total)}
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    _, s2, m = safl_round(cfg, _linear_loss, params, wrapped,
                          _round_batch(), jax.random.key(0), plan=plan,
                          part_mask=mask, codec=codec)
    ef = np.asarray(s2["ef"])
    np.testing.assert_array_equal(ef[1], 0.0)
    np.testing.assert_array_equal(ef[3], 0.0)
    assert np.abs(ef[0]).sum() > 0
    assert np.abs(ef[2]).sum() > 0


def test_streamed_codec_round_matches_materialized():
    """microbatch=2 folds the same quantized rows (global-index RNG), so
    params and EF memory agree with the materialized codec round to float
    tolerance (pin class 3: across the stream/materialize families)."""
    cfg, plan, _, fresh = _safl_setup()
    codec = CodecConfig(bits=8)

    def run(mb):
        params, opt = fresh()
        wrapped = {"opt": opt, "ef": init_codec_state(codec, G, plan.b_total)}
        return safl_round(cfg, _linear_loss, params, wrapped,
                          _round_batch(), jax.random.key(2), plan=plan,
                          microbatch=mb, codec=codec)
    p_mat, s_mat, m_mat = run(None)
    p_str, s_str, m_str = run(2)
    for a, b in zip(jax.tree.leaves(p_mat), jax.tree.leaves(p_str)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_mat["ef"]),
                               np.asarray(s_str["ef"]), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(m_mat["uplink_bits"]),
                                  np.asarray(m_str["uplink_bits"]))


# ---------------------------------------------------------------------------
# measured bits on the wire
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 1])
def test_uplink_bits_is_measured_wire_size(bits):
    cfg, plan, _, fresh = _safl_setup()
    codec = CodecConfig(bits=bits, error_feedback=False)
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    _, _, m = safl_round(cfg, _linear_loss, *fresh(), _round_batch(),
                         jax.random.key(0), plan=plan, part_mask=mask,
                         codec=codec)
    want = (plan.b_total * bits + 32) * 3          # 3 transmitting clients
    assert float(m["uplink_bits"]) == want
    assert float(measured_uplink_bits(codec, plan.b_total, eff_mask=mask,
                                      num_clients=G)) == want
    # the wire size is strictly below the float32 payload it replaces
    assert want < 32 * plan.b_total * 3


def test_uplink_bits_measured_through_run_scan_history():
    cfg, plan, round_fn, fresh = _safl_setup()
    codec = CodecConfig(bits=8)
    params, opt = fresh()
    wrapped = {"opt": opt, "ef": init_codec_state(codec, G, plan.b_total)}
    _, _, hist = run_scan(functools.partial(round_fn, codec=codec),
                          _LinearSampler(), params, wrapped, rounds=3,
                          key=jax.random.key(4))
    np.testing.assert_array_equal(np.asarray(hist["uplink_bits"]),
                                  float((plan.b_total * 8 + 32) * G))
    assert np.isfinite(hist["loss"]).all()


# ---------------------------------------------------------------------------
# rejection matrix
# ---------------------------------------------------------------------------

def test_fedopt_rejects_codec():
    cfg, plan, _, fresh = _safl_setup()
    with pytest.raises(ValueError, match="no sketch payload"):
        fedopt_round(cfg, _linear_loss, *fresh(), _round_batch(),
                     jax.random.key(0), codec=CodecConfig(bits=8))


@pytest.mark.parametrize("clip", [False, True])
def test_codec_with_telemetry_rejected(clip):
    cfg, plan, _, fresh = _safl_setup(clip=clip)
    fn = clipped_safl_round if clip else safl_round
    with pytest.raises(ValueError, match="telemetry"):
        fn(cfg, _linear_loss, *fresh(), _round_batch(), jax.random.key(0),
           plan=plan, telemetry=Telemetry(), codec=CodecConfig(bits=8))


def test_codec_config_validates_bits():
    with pytest.raises(AssertionError, match="bits"):
        CodecConfig(bits=4)
