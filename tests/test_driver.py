"""On-device multi-round driver (launch/driver.py) + device sampler.

Pins the two contracts ISSUE 2 cares about:
  * N scanned rounds are bit-identical to N host-loop rounds (same keys,
    same device-sampled batches) for safl, fetchsgd and topk_ef;
  * the device-side sampler is a pure function of (round, client, seed).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import AdaConfig
from repro.core.baselines import (BaselineConfig, baseline_round,
                                  init_baseline_state)
from repro.core.packed import make_packing_plan
from repro.core.safl import SAFLConfig, init_safl, safl_round
from repro.core.sketch import SketchConfig
from repro.data import BigramLMData, LMDataConfig
from repro.launch.driver import run_host_loop, run_scan
from repro.models import ModelConfig, init_params, loss_fn

MODEL = ModelConfig(name="drv", arch_type="dense", num_layers=1, d_model=32,
                    num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=64)
DATA_CFG = LMDataConfig(vocab_size=64, seq_len=16, num_clients=3, alpha=0.05)


def _sampler(batch_per_client=4, local_steps=2, cfg=DATA_CFG):
    return BigramLMData(cfg).device_sampler(batch_per_client, local_steps)


def _setup(algo):
    params = init_params(MODEL, jax.random.key(0))
    loss = lambda p, b: loss_fn(MODEL, p, b)
    if algo == "safl":
        cfg = SAFLConfig(
            sketch=SketchConfig(kind="countsketch", ratio=0.1, min_b=8),
            server=AdaConfig(name="amsgrad", lr=0.01),
            client_lr=0.5, local_steps=2)
        plan = make_packing_plan(cfg.sketch, params)
        round_fn = functools.partial(safl_round, cfg, loss, plan=plan)
        init_state = lambda p: init_safl(cfg, p)
    else:
        cfg = BaselineConfig(
            name=algo, client_lr=0.5, local_steps=2, topk_ratio=0.25,
            sketch=SketchConfig(kind="countsketch", ratio=0.25, min_b=8))
        plan = make_packing_plan(cfg.sketch, params)
        round_fn = functools.partial(baseline_round, cfg, loss, plan=plan)
        init_state = lambda p: init_baseline_state(
            cfg, p, DATA_CFG.num_clients, plan=plan)

    def fresh():
        p = init_params(MODEL, jax.random.key(0))
        return p, init_state(p)

    return round_fn, fresh


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("algo", ["safl", "fetchsgd", "topk_ef"])
def test_scan_matches_host_loop_bitwise(algo):
    """N driver-scanned rounds == N host-loop rounds, bit for bit (same
    fold_in(key, t) chain, same device-sampled batches)."""
    rounds = 3
    smp = _sampler()
    round_fn, fresh = _setup(algo)
    key = jax.random.key(42)
    p_host, s_host, h_host = run_host_loop(round_fn, smp, *fresh(),
                                           rounds=rounds, key=key,
                                           donate=False)
    # donate=True on the scan side also exercises the donated-carry path
    p_scan, s_scan, h_scan = run_scan(round_fn, smp, *fresh(),
                                      rounds=rounds, key=key, donate=True)
    assert h_scan["loss"].shape == (rounds,)
    np.testing.assert_array_equal(h_host["loss"], h_scan["loss"])
    _assert_trees_equal(p_host, p_scan)
    _assert_trees_equal(s_host, s_scan)


def test_scan_chunking_invariant():
    """Chunked dispatch (2+2) is bit-identical to one 4-round dispatch, and
    the stitched metric history matches."""
    smp = _sampler()
    round_fn, fresh = _setup("safl")
    key = jax.random.key(7)
    p1, s1, h1 = run_scan(round_fn, smp, *fresh(), rounds=4, key=key,
                          bits_per_round=64)
    p2, s2, h2 = run_scan(round_fn, smp, *fresh(), rounds=4, key=key,
                          chunk_size=2, bits_per_round=64)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    np.testing.assert_array_equal(h1["uplink_bits"], np.full(4, 64.0))
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(s1, s2)


def test_scan_on_chunk_callback_sees_progress():
    smp = _sampler()
    round_fn, fresh = _setup("safl")
    seen = []
    run_scan(round_fn, smp, *fresh(), rounds=4, key=jax.random.key(0),
             chunk_size=2, on_chunk=lambda t, p, s, h: seen.append(
                 (t, h["loss"].shape)))
    assert seen == [(2, (2,)), (4, (2,))]


def test_scan_kwargs_fn_threads_round_index():
    """kwargs_fn rides per-round traced kwargs (e.g. lr_scale) into the
    round; lr_scale=0 must freeze the server."""
    smp = _sampler()
    round_fn, fresh = _setup("safl")
    p0, _ = fresh()
    p, s, _ = run_scan(round_fn, smp, *fresh(), rounds=2,
                       key=jax.random.key(0),
                       kwargs_fn=lambda t: {"lr_scale": jnp.zeros(())})
    _assert_trees_equal(p, p0)


# ---------------------------------------------------------------------------
# one driver interface serves every round variant
# ---------------------------------------------------------------------------

class _LinearSampler:
    """Minimal sampler-protocol impl over the linear regression task: shows
    the driver is generic in the data source, and keeps the all-variant
    parity sweep cheap."""

    def __init__(self, clients=4, local_steps=2, mb=4):
        self.shape = (clients, local_steps, mb, 16)
        self.W = np.asarray(jax.random.normal(jax.random.key(1), (16, 4)))

    def init_state(self):
        return {"W": jnp.asarray(self.W, jnp.float32)}

    def sample(self, state, t):
        x = jax.random.normal(jax.random.fold_in(jax.random.key(11), t),
                              self.shape)
        return state, {"x": x, "y": x @ state["W"]}


def _linear_loss(params, batch):
    return jnp.mean((batch["x"] @ params["W"] - batch["y"]) ** 2)


ALL_BASELINES = ["fedavg", "fedopt", "topk_ef", "fetchsgd", "onebit_adam",
                 "marina", "cocktail"]


@pytest.mark.parametrize("name", ALL_BASELINES)
def test_every_baseline_variant_scans(name):
    """All seven baseline_round variants run through the one driver
    interface, and scan == host loop bitwise."""
    k = 1 if name == "marina" else 2            # marina wants K=1 semantics
    smp = _LinearSampler(local_steps=k)
    cfg = BaselineConfig(name=name, client_lr=0.05, local_steps=k,
                         topk_ratio=0.25, onebit_warmup=2,
                         server=AdaConfig(name="adam", lr=0.05)
                         if name == "onebit_adam" else AdaConfig(name="sgd",
                                                                 lr=0.5),
                         sketch=SketchConfig(kind="countsketch", ratio=0.25,
                                             min_b=8))
    params0 = {"W": jnp.zeros((16, 4))}
    plan = make_packing_plan(cfg.sketch, params0)
    round_fn = functools.partial(baseline_round, cfg, _linear_loss, plan=plan)
    fresh = lambda: ({"W": jnp.zeros((16, 4))},
                     init_baseline_state(cfg, {"W": jnp.zeros((16, 4))}, 4,
                                         plan=plan))
    key = jax.random.key(5)
    p1, s1, h1 = run_host_loop(round_fn, smp, *fresh(), rounds=3, key=key,
                               donate=False)
    p2, s2, h2 = run_scan(round_fn, smp, *fresh(), rounds=3, key=key)
    assert np.isfinite(h2["loss"]).all()
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(s1, s2)
    assert int(s2["round"]) == 3


def test_clipped_safl_scans():
    from repro.core.clipped import ClippedSAFLConfig, clipped_safl_round
    smp = _LinearSampler()
    base = SAFLConfig(
        sketch=SketchConfig(kind="countsketch", ratio=0.25, min_b=8),
        server=AdaConfig(name="amsgrad", lr=0.05), client_lr=0.05,
        local_steps=2)
    cfg = ClippedSAFLConfig(base=base, clip_tau=0.5)
    params0 = {"W": jnp.zeros((16, 4))}
    plan = make_packing_plan(base.sketch, params0)
    round_fn = functools.partial(clipped_safl_round, cfg, _linear_loss,
                                 plan=plan)
    fresh = lambda: ({"W": jnp.zeros((16, 4))},
                     init_safl(base, {"W": jnp.zeros((16, 4))}))
    key = jax.random.key(5)
    p1, s1, h1 = run_host_loop(round_fn, smp, *fresh(), rounds=3, key=key,
                               donate=False)
    p2, s2, h2 = run_scan(round_fn, smp, *fresh(), rounds=3, key=key)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(p1, p2)


# ---------------------------------------------------------------------------
# device-side sampler determinism
# ---------------------------------------------------------------------------

def test_device_sampler_pure_in_round_client_seed():
    """Tokens of (round t, client c) depend ONLY on (t, c, cfg.seed)."""
    s1 = _sampler()
    b1 = np.asarray(s1.round_batch(5)["tokens"])
    # same sampler, same round: identical
    np.testing.assert_array_equal(b1, np.asarray(s1.round_batch(5)["tokens"]))
    # a FRESH sampler over the same dataset: identical
    b2 = np.asarray(_sampler().round_batch(5)["tokens"])
    np.testing.assert_array_equal(b1, b2)
    # different round: different tokens
    b3 = np.asarray(s1.round_batch(6)["tokens"])
    assert not np.array_equal(b1, b3)
    # different clients draw different streams even under iid transitions
    assert not np.array_equal(b1[0], b1[1])
    # client c's stream does not depend on how many clients exist (iid data:
    # the transition table of the shared prefix is identical)
    wide = _sampler(cfg=LMDataConfig(vocab_size=64, seq_len=16,
                                     num_clients=5, alpha=0.05))
    b5 = np.asarray(wide.round_batch(5)["tokens"])
    np.testing.assert_array_equal(b1, b5[:3])


def test_device_sampler_shapes_and_range():
    smp = _sampler(batch_per_client=6, local_steps=3)
    toks = np.asarray(smp.round_batch(0)["tokens"])
    assert toks.shape == (3, 3, 2, 16)          # (G, K, mb, seq)
    assert toks.dtype == np.int32
    assert toks.min() >= 0 and toks.max() < 64


def test_host_round_batch_matches_device_sampler_bitwise():
    """The legacy-shaped host pipeline (Python loop over positions, numpy
    out) draws the exact tokens of the scanned device sampler -- this is
    what makes the benchmark's host-loop and _scan rows comparable at f32
    tolerance."""
    smp = _sampler(batch_per_client=6, local_steps=3)
    for t in (0, 4):
        np.testing.assert_array_equal(
            np.asarray(smp.round_batch(t)["tokens"]),
            smp.host_round_batch(t)["tokens"])


def test_device_sampler_jittable():
    """sample() must trace: the whole point is use inside lax.scan."""
    smp = _sampler()
    st = smp.init_state()
    jit_sample = jax.jit(smp.sample)
    _, b1 = jit_sample(st, jnp.asarray(3, jnp.int32))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(smp.round_batch(3)["tokens"]))
