"""Data pipeline, schedules, checkpointing, intrinsic dimension."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.intrinsic_dim import intrinsic_dimension, make_hvp
from repro.data import (BigramLMData, ClsDataConfig, GaussianClsData,
                        LMDataConfig)
from repro.optim import constant, cosine, inv_sqrt, sketch_size_schedule


def test_lm_data_shapes_and_determinism():
    data = BigramLMData(LMDataConfig(vocab_size=32, seq_len=8, num_clients=3))
    b1 = data.round_batch(4, 2, seed=7)
    b2 = data.round_batch(4, 2, seed=7)
    assert b1["tokens"].shape == (3, 2, 2, 8)
    np.testing.assert_array_equal(np.array(b1["tokens"]),
                                  np.array(b2["tokens"]))
    assert int(b1["tokens"].max()) < 32


def test_lm_data_heterogeneity():
    """Dirichlet-skewed clients produce different token statistics."""
    iid = BigramLMData(LMDataConfig(vocab_size=16, seq_len=64, num_clients=2,
                                    heterogeneity=0.0))
    het = BigramLMData(LMDataConfig(vocab_size=16, seq_len=64, num_clients=2,
                                    heterogeneity=1.0))
    assert np.allclose(iid.trans[0], iid.trans[1])
    assert not np.allclose(het.trans[0], het.trans[1])


def test_cls_data_label_skew():
    d = GaussianClsData(ClsDataConfig(num_clients=3, dirichlet_alpha=0.1))
    probs = d.label_probs
    assert probs.shape == (3, 10)
    assert not np.allclose(probs[0], probs[1])
    b = d.round_batch(8, 2, seed=0)
    assert b["x"].shape == (3, 2, 4, 32)
    assert b["y"].shape == (3, 2, 4)


def test_schedules():
    t = jnp.arange(10)
    assert float(constant()(t)[5]) == 1.0
    s = inv_sqrt(1.0)(t)
    assert float(s[0]) == 1.0 and float(s[3]) == 0.5
    c = cosine(100, warmup=10)(jnp.asarray([0, 10, 100]))
    assert float(c[0]) == 0.0 and abs(float(c[1]) - 1.0) < 1e-5
    assert float(c[2]) < 0.01
    sk = sketch_size_schedule(0.01, 100, final_frac=4.0)
    assert sk(0) == 0.01 and abs(sk(100) - 0.04) < 1e-9


def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, tree, step=7)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = restore_checkpoint(path, like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.array(a, np.float32),
                                      np.array(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"w": jnp.ones((3,))}
    path = os.path.join(tmp_path, "c2")
    save_checkpoint(path, tree)
    with pytest.raises(ValueError):
        restore_checkpoint(path, {"w": jnp.ones((4,))})


def test_hvp_and_intrinsic_dim_quadratic():
    """For L(x) = 0.5 x^T A x the Hessian is A: intrinsic dim and lambda_max
    must match the known spectrum."""
    eigs = jnp.array([4.0, 2.0, 1.0, 0.5, 0.25, 0.1, 0.05, 0.0])
    d = eigs.shape[0]
    q, _ = jnp.linalg.qr(jax.random.normal(jax.random.key(0), (d, d)))
    A = q @ jnp.diag(eigs) @ q.T

    def loss_fn(params, batch):
        x = params["x"]
        return 0.5 * x @ A @ x

    params = {"x": jax.random.normal(jax.random.key(1), (d,))}
    mv, dim = make_hvp(loss_fn, params, None)
    v = jax.random.normal(jax.random.key(2), (d,))
    np.testing.assert_allclose(np.array(mv(v)), np.array(A @ v),
                               rtol=1e-4, atol=1e-5)

    out = intrinsic_dimension(loss_fn, params, None, num_iters=d,
                              num_probes=4)
    want_I = float(jnp.abs(eigs).sum() / jnp.abs(eigs).max())
    assert abs(out["lambda_max"] - 4.0) < 0.05
    assert abs(out["intrinsic_dim"] - want_I) / want_I < 0.35
