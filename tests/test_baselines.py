"""Baseline algorithms (paper §5 comparisons) run and make progress."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import AdaConfig
from repro.core.baselines import (BaselineConfig, baseline_round,
                                  init_baseline_state, randk_unbiased,
                                  sign_quant, topk_mask, uplink_bits)
from repro.core.safl import split_client_batches
from repro.core.sketch import SketchConfig


def _task():
    key = jax.random.key(0)
    W = jax.random.normal(jax.random.fold_in(key, 1), (16, 4))

    def make_batch(k, n=32):
        x = jax.random.normal(k, (n, 16))
        return {"x": x, "y": x @ W}

    def loss_fn(params, batch):
        return jnp.mean((batch["x"] @ params["W"] - batch["y"]) ** 2)

    return {"W": jnp.zeros((16, 4))}, loss_fn, make_batch


CONFIGS = [
    BaselineConfig(name="fedavg", client_lr=0.05, local_steps=2),
    BaselineConfig(name="topk_ef", client_lr=0.05, local_steps=2,
                   topk_ratio=0.25),
    BaselineConfig(name="fetchsgd", client_lr=0.05, local_steps=2,
                   topk_ratio=0.25, fetchsgd_momentum=0.9,
                   sketch=SketchConfig(kind="countsketch", ratio=0.25, min_b=8)),
    BaselineConfig(name="onebit_adam", client_lr=0.05, local_steps=2,
                   server=AdaConfig(name="adam", lr=0.05), onebit_warmup=15),
    BaselineConfig(name="marina", client_lr=0.05, local_steps=1,
                   server=AdaConfig(name="sgd", lr=0.5), topk_ratio=0.25),
    BaselineConfig(name="cocktail", client_lr=0.05, local_steps=2,
                   topk_ratio=0.25, server=AdaConfig(name="sgd", lr=0.5)),
]


@pytest.mark.parametrize("cfg", CONFIGS, ids=[c.name for c in CONFIGS])
def test_baseline_reduces_loss(cfg):
    params, loss_fn, make_batch = _task()
    state = init_baseline_state(cfg, params, 4)
    rj = jax.jit(functools.partial(baseline_round, cfg, loss_fn))
    key = jax.random.key(3)
    first = None
    for t in range(60):
        b = split_client_batches(make_batch(jax.random.fold_in(key, t)),
                                 4, cfg.local_steps)
        params, state, m = rj(params, state, b, jax.random.key(100 + t))
        if first is None:
            first = float(m["loss"])
    assert jnp.isfinite(m["loss"])
    assert float(m["loss"]) < first, (cfg.name, first, float(m["loss"]))


def test_topk_mask():
    v = jnp.array([3.0, -1.0, 0.5, -4.0])
    out = np.array(topk_mask(v, 2))
    np.testing.assert_array_equal(out, [3.0, 0.0, 0.0, -4.0])


def test_randk_unbiased_statistics():
    v = jnp.arange(1.0, 11.0)
    acc = jnp.zeros(10)
    T = 400
    for t in range(T):
        acc = acc + randk_unbiased(jax.random.key(t), v, 3)
    mean = np.array(acc / T)
    np.testing.assert_allclose(mean, np.arange(1.0, 11.0), rtol=0.35)


def test_sign_quant_preserves_l1_scale():
    v = jnp.array([2.0, -4.0, 6.0])
    out = np.array(sign_quant(v))
    np.testing.assert_allclose(np.abs(out), 4.0, rtol=1e-6)
    np.testing.assert_array_equal(np.sign(out), [1, -1, 1])


def test_uplink_bits_ordering():
    """Compression baselines transmit (much) less than FedAvg (Table 1)."""
    params = {"w": jnp.zeros((100000,))}
    full = uplink_bits(BaselineConfig(name="fedavg"), params)
    for cfg in CONFIGS[1:]:
        assert uplink_bits(cfg, params) < full, cfg.name


def test_error_feedback_memory_accumulates():
    cfg = BaselineConfig(name="topk_ef", client_lr=0.1, local_steps=1,
                         topk_ratio=0.05)
    params, loss_fn, make_batch = _task()
    state = init_baseline_state(cfg, params, 2)
    b = split_client_batches(make_batch(jax.random.key(0), 16), 2, 1)
    _, state, _ = baseline_round(cfg, loss_fn, params, state, b,
                                 jax.random.key(1))
    assert float(jnp.abs(state["err"]["W"]).sum()) > 0


@pytest.mark.parametrize("name", ["fedavg", "topk_ef", "fetchsgd", "marina"])
def test_baseline_round_is_purely_functional(name):
    """The input state dict must come back untouched: an in-place mutation
    (`state["err"] = ...`) is an aliasing bug under buffer donation and makes
    the state an unsafe lax.scan carry (ISSUE 2)."""
    cfg = next(c for c in CONFIGS if c.name == name)
    params, loss_fn, make_batch = _task()
    state = init_baseline_state(cfg, params, 4)
    keys_before = set(state)
    snapshot = jax.tree.map(lambda x: np.array(x), state)
    b = split_client_batches(make_batch(jax.random.key(0)), 4,
                             cfg.local_steps)
    _, state2, _ = baseline_round(cfg, loss_fn, params, state, b,
                                  jax.random.key(1))
    assert state2 is not state
    assert set(state) == keys_before
    jax.tree.map(lambda x, ref: np.testing.assert_array_equal(
        np.asarray(x), ref), state, snapshot)
    assert int(state["round"]) == 0 and int(state2["round"]) == 1
