"""Integration tests for the SAFL round (Algorithm 1)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import AdaConfig
from repro.core.safl import (SAFLConfig, client_delta, fedopt_round,
                             init_safl, safl_round, split_client_batches,
                             uplink_bits_per_round)
from repro.core.sketch import SketchConfig


def _task():
    key = jax.random.key(0)
    W = jax.random.normal(jax.random.fold_in(key, 1), (16, 4))

    def make_batch(k, n=32):
        x = jax.random.normal(k, (n, 16))
        return {"x": x, "y": x @ W}

    def loss_fn(params, batch):
        return jnp.mean((batch["x"] @ params["W"] - batch["y"]) ** 2)

    params = {"W": jnp.zeros((16, 4))}
    return params, loss_fn, make_batch


def _run(cfg, rounds=150, clients=4, k=2):
    params, loss_fn, make_batch = _task()
    opt = init_safl(cfg, params)
    rj = jax.jit(functools.partial(safl_round, cfg, loss_fn))
    key = jax.random.key(9)
    for t in range(rounds):
        b = split_client_batches(make_batch(jax.random.fold_in(key, t)), clients, k)
        params, opt, m = rj(params, opt, b, jax.random.key(t))
    return float(m["loss"])


def test_safl_converges_uncompressed():
    cfg = SAFLConfig(sketch=SketchConfig(kind="none"),
                     server=AdaConfig(name="amsgrad", lr=0.05),
                     client_lr=0.05, local_steps=2)
    assert _run(cfg, rounds=80) < 0.05


@pytest.mark.parametrize("kind", ["countsketch", "srht", "gaussian"])
def test_safl_converges_sketched(kind):
    cfg = SAFLConfig(sketch=SketchConfig(kind=kind, ratio=0.5, min_b=8),
                     server=AdaConfig(name="amsgrad", lr=0.05),
                     client_lr=0.05, local_steps=2)
    assert _run(cfg, rounds=250) < 0.2


def test_larger_sketch_converges_faster():
    """The paper's monotonicity claim (Fig. 1 right): training error after a
    fixed budget decreases with sketch size b."""
    losses = {}
    for ratio in (0.125, 1.0):
        cfg = SAFLConfig(
            sketch=SketchConfig(kind="countsketch", ratio=ratio, min_b=4),
            server=AdaConfig(name="amsgrad", lr=0.05),
            client_lr=0.05, local_steps=2)
        losses[ratio] = _run(cfg, rounds=120)
    assert losses[1.0] < losses[0.125]


def test_sketch_none_equals_fedopt():
    """SAFL with the identity compressor IS FedOPT (same trajectory)."""
    params, loss_fn, make_batch = _task()
    cfg = SAFLConfig(sketch=SketchConfig(kind="none"),
                     server=AdaConfig(name="amsgrad", lr=0.05),
                     client_lr=0.05, local_steps=2)
    p1, o1 = params, init_safl(cfg, params)
    p2, o2 = params, init_safl(cfg, params)
    for t in range(5):
        b = split_client_batches(make_batch(jax.random.key(t)), 4, 2)
        p1, o1, _ = safl_round(cfg, loss_fn, p1, o1, b, jax.random.key(t))
        p2, o2, _ = fedopt_round(cfg, loss_fn, p2, o2, b, jax.random.key(t))
    np.testing.assert_allclose(np.array(p1["W"]), np.array(p2["W"]), atol=1e-6)


def test_client_delta_is_k_sgd_steps():
    params, loss_fn, make_batch = _task()
    cfg = SAFLConfig(client_lr=0.1, local_steps=3, remat_local=False)
    batch = make_batch(jax.random.key(5), n=6)
    mbs = jax.tree.map(lambda x: x.reshape(3, 2, *x.shape[1:]), batch)
    delta, _ = client_delta(cfg, loss_fn, params, mbs, jnp.asarray(0.1))
    # manual 3 SGD steps
    p = params
    for k in range(3):
        mb = jax.tree.map(lambda x: x[k], mbs)
        g = jax.grad(loss_fn)(p, mb)
        p = jax.tree.map(lambda a, b: a - 0.1 * b, p, g)
    np.testing.assert_allclose(np.array(delta["W"]),
                               np.array(params["W"] - p["W"]), rtol=1e-5)


def test_sketch_average_equals_average_sketch():
    """Property 1 in action: averaging sketches == sketching the average,
    so the server needs no second compression round."""
    from repro.core.sketch import sketch_tree
    cfg = SketchConfig(kind="countsketch", ratio=0.5, min_b=8)
    key = jax.random.key(2)
    trees = [{"w": jax.random.normal(jax.random.key(i), (64,))} for i in range(4)]
    sks = [sketch_tree(cfg, key, t) for t in trees]
    avg_sk = jax.tree.map(lambda *xs: sum(xs) / 4, *sks)
    mean_tree = jax.tree.map(lambda *xs: sum(xs) / 4, *trees)
    sk_avg = sketch_tree(cfg, key, mean_tree)
    np.testing.assert_allclose(np.array(avg_sk["w"]), np.array(sk_avg["w"]),
                               atol=1e-5)


def test_uplink_bits_scale_with_ratio():
    params = {"w": jnp.zeros((10000,))}
    mk = lambda r: SAFLConfig(sketch=SketchConfig(
        kind="countsketch", ratio=r, min_b=1))
    assert uplink_bits_per_round(mk(0.01), params) * 10 == \
        uplink_bits_per_round(mk(0.1), params)


def test_split_client_batches_shapes():
    b = {"tokens": jnp.zeros((24, 7))}
    out = split_client_batches(b, 4, 3)
    assert out["tokens"].shape == (4, 3, 2, 7)


def test_metrics_finite_and_moments_populated():
    params, loss_fn, make_batch = _task()
    cfg = SAFLConfig(sketch=SketchConfig(kind="countsketch", ratio=0.25, min_b=4),
                     server=AdaConfig(name="amsgrad", lr=0.01),
                     client_lr=0.05, local_steps=2)
    opt = init_safl(cfg, params)
    b = split_client_batches(make_batch(jax.random.key(0)), 4, 2)
    p, opt, m = safl_round(cfg, loss_fn, params, opt, b, jax.random.key(1))
    assert jnp.isfinite(m["loss"])
    assert float(jnp.abs(opt["m"]["W"]).sum()) > 0
    assert float(opt["vhat"]["W"].max()) > 0
