"""Multi-chunk checkpoint resume (ISSUE 4 satellite, ROADMAP "restart path").

examples/train_lm.py checkpoints a resumable ``(t, key)`` cursor at every
chunk boundary: because every per-round stream (device-sampled data, cohort
masks, sketch operators, LR schedule) is a pure function of the ABSOLUTE
round index under the base key, restoring ``(params, opt, cursor)`` and
re-entering ``run_scan(start_round=t)`` must replay the uninterrupted
trajectory bit for bit.  These tests exercise exactly that cursor format
through ``checkpoint.io``'s npz round-trip (f32/u32/i32 exact).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.adaptive import AdaConfig
from repro.core.packed import make_packing_plan
from repro.core.safl import SAFLConfig, init_safl, safl_round
from repro.core.sketch import SketchConfig
from repro.launch.driver import run_scan

G = 3


class _LinearSampler:
    """Minimal driver-protocol sampler (pure fn of the absolute round)."""

    def __init__(self, clients=G, local_steps=2, mb=4):
        self.shape = (clients, local_steps, mb, 16)
        self.W = np.asarray(jax.random.normal(jax.random.key(1), (16, 4)))

    def init_state(self):
        return {"W": jnp.asarray(self.W, jnp.float32)}

    def sample(self, state, t):
        x = jax.random.normal(jax.random.fold_in(jax.random.key(11), t),
                              self.shape)
        return state, {"x": x, "y": x @ state["W"]}


def _linear_loss(params, batch):
    return jnp.mean((batch["x"] @ params["W"] - batch["y"]) ** 2)


def _setup():
    cfg = SAFLConfig(
        sketch=SketchConfig(kind="countsketch", ratio=0.25, min_b=8),
        server=AdaConfig(name="amsgrad", lr=0.05), client_lr=0.05,
        local_steps=2)
    params0 = {"W": jnp.zeros((16, 4))}
    plan = make_packing_plan(cfg.sketch, params0)
    round_fn = functools.partial(safl_round, cfg, _linear_loss, plan=plan)
    fresh = lambda: ({"W": jnp.zeros((16, 4))},
                     init_safl(cfg, {"W": jnp.zeros((16, 4))}))
    return round_fn, fresh


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _cursor_state(params, opt, t, key):
    """The exact checkpoint payload examples/train_lm.py saves per chunk."""
    return {"params": params, "opt": opt,
            "cursor": {"t": jnp.asarray(t),
                       "key": jax.random.key_data(key)}}


def test_resume_from_chunk_boundary_is_bit_identical(tmp_path):
    """Kill after the chunk that crosses round 4, restore the (t, key)
    cursor, resume with start_round -- final params/opt and the stitched
    loss history match the uninterrupted 6-round run bitwise."""
    round_fn, fresh = _setup()
    smp = _LinearSampler()
    key = jax.random.key(3)
    ckpt = str(tmp_path / "ck")

    # uninterrupted reference
    p_ref, s_ref, h_ref = run_scan(round_fn, smp, *fresh(), rounds=6,
                                   key=key, chunk_size=2)

    # interrupted run: only rounds [0, 4), checkpointing every chunk
    def on_chunk(t_done, p, s, hist):
        save_checkpoint(ckpt, _cursor_state(p, s, t_done, key), step=t_done)

    _, _, h_a = run_scan(round_fn, smp, *fresh(), rounds=4, key=key,
                         chunk_size=2, on_chunk=on_chunk)

    # restart: a FRESH process would rebuild like-structured zeros, restore,
    # and re-enter the driver at the cursor
    like = _cursor_state(*fresh(), 0, key)
    state, step = restore_checkpoint(ckpt, like)
    assert step == 4 and int(state["cursor"]["t"]) == 4
    k2 = jax.random.wrap_key_data(state["cursor"]["key"])
    p_b, s_b, h_b = run_scan(round_fn, smp, state["params"], state["opt"],
                             rounds=6, key=k2, chunk_size=2,
                             start_round=int(state["cursor"]["t"]))

    assert h_b["loss"].shape == (2,)
    np.testing.assert_array_equal(
        np.concatenate([h_a["loss"], h_b["loss"]]), h_ref["loss"])
    _assert_trees_equal(p_b, p_ref)
    _assert_trees_equal(s_b, s_ref)


def test_resume_is_chunk_split_invariant(tmp_path):
    """Resuming at a round that is NOT a multiple of the new chunk size
    (start 4, chunk 3 -> tail chunks 2) still lands on the reference
    trajectory: nothing about the streams depends on chunk boundaries."""
    round_fn, fresh = _setup()
    smp = _LinearSampler()
    key = jax.random.key(8)
    ckpt = str(tmp_path / "ck2")

    p_ref, s_ref, h_ref = run_scan(round_fn, smp, *fresh(), rounds=7,
                                   key=key)
    p4, s4, _ = run_scan(round_fn, smp, *fresh(), rounds=4, key=key,
                         chunk_size=4)
    save_checkpoint(ckpt, _cursor_state(p4, s4, 4, key), step=4)

    state, _ = restore_checkpoint(ckpt, _cursor_state(*fresh(), 0, key))
    p_b, s_b, h_b = run_scan(
        round_fn, smp, state["params"], state["opt"], rounds=7,
        key=jax.random.wrap_key_data(state["cursor"]["key"]), chunk_size=3,
        start_round=int(state["cursor"]["t"]))
    assert h_b["loss"].shape == (3,)
    np.testing.assert_array_equal(h_b["loss"], h_ref["loss"][4:])
    _assert_trees_equal(p_b, p_ref)
    _assert_trees_equal(s_b, s_ref)


def test_resume_with_participation_and_lr_schedule(tmp_path):
    """The cursor also pins cohort masks and kwargs_fn streams: a resumed
    run under partial participation + a round-indexed LR scale matches the
    uninterrupted trajectory bitwise (both are pure functions of the
    absolute round index)."""
    from repro.fed import UniformParticipation
    round_fn, fresh = _setup()
    smp = _LinearSampler()
    key = jax.random.key(5)
    pol = UniformParticipation(G, frac=0.5, seed=2)
    sched = lambda t: {"lr_scale": 1.0 / (1.0 + 0.1 * t)}
    ckpt = str(tmp_path / "ck3")

    p_ref, s_ref, h_ref = run_scan(round_fn, smp, *fresh(), rounds=6,
                                   key=key, participation=pol,
                                   kwargs_fn=sched)
    p3, s3, _ = run_scan(round_fn, smp, *fresh(), rounds=3, key=key,
                         participation=pol, kwargs_fn=sched)
    save_checkpoint(ckpt, _cursor_state(p3, s3, 3, key), step=3)

    state, _ = restore_checkpoint(ckpt, _cursor_state(*fresh(), 0, key))
    p_b, s_b, h_b = run_scan(
        round_fn, smp, state["params"], state["opt"], rounds=6,
        key=jax.random.wrap_key_data(state["cursor"]["key"]),
        participation=pol, kwargs_fn=sched,
        start_round=int(state["cursor"]["t"]))
    np.testing.assert_array_equal(h_b["loss"], h_ref["loss"][3:])
    _assert_trees_equal(p_b, p_ref)
    _assert_trees_equal(s_b, s_ref)
