"""Multi-pod scanned mesh driver (launch/train.py, DESIGN §8-§9).

Pins the ISSUE 4 + ISSUE 5 contracts on an 8-forced-CPU-device host mesh:

  * scanned mesh rounds (``run_mesh_scan``: one ``lax.scan`` OUTSIDE the
    shard_map round, donated (params, opt, data_state, key) carries) are
    bit-identical to per-round jitted mesh steps (``run_mesh_host_loop``)
    for safl AND fedopt, on cross_device and cross_silo topologies;
  * chunk-split invariance: chunked dispatch == one-dispatch, bitwise;
  * donation safety: chunk_size=1 rethreads every donated carry across
    dispatches without aliasing crashes;
  * the plan-routed shard-local sketch (``make_sharded_packing_plan`` +
    packed sk/desk inside shard_map) equals the per-leaf reference loop;
  * the repro.fed hooks (DESIGN §9): an all-ones participation mask and a
    delay=0 staleness buffer are pinned BITWISE to the hookless PR-4
    trajectories; masked/buffered scans match the hooked per-round loop
    and are chunk-split invariant; weighted (importance) masks are
    rejected by the mesh buffer path with a clear error.

Device policy (DESIGN §5): the 8-device flag must NOT leak into the main
suite, so when this module is collected on a single-device session it
re-runs itself in a subprocess with
``--xla_force_host_platform_device_count=8`` (the mini-dry-run pattern);
CI additionally runs the direct tests in a dedicated 8-device job step.
Both topologies run on BOTH jax stacks: on jax 0.4.x (whose bundled XLA
hard-crashes on the partial-manual client-delta shard_map,
IsManualSubgroup CHECK) cross_device routes through the vmap fallback,
which the new stack pins bitwise against the shard_map formulation
(test_vmap_fallback_matches_shard_map).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.launch.train as train_mod
from repro.core.adaptive import AdaConfig
from repro.core.packed import make_sharded_packing_plan
from repro.core.safl import SAFLConfig, init_safl
from repro.core.sketch import SketchConfig
from repro.data import BigramLMData, LMDataConfig
from repro.fed import (AsyncConfig, CodecConfig, FaultConfig, FaultTable,
                       FixedCohort, FullParticipation,
                       ImportanceParticipation, SentinelConfig,
                       UniformParticipation)
from repro.fed import BYZANTINE as FAULT_BYZ
from repro.fed import DROP as FAULT_DROP
from repro.fed import NAN as FAULT_NAN
from repro.fed import OK as FAULT_OK
from repro.launch.mesh import _mesh
from repro.launch.train import (_mesh_pspecs, init_mesh_async_state,
                                make_fedopt_scan_fn, make_fedopt_train_step,
                                make_safl_train_step, mesh_sampler,
                                num_clients_of, run_mesh_host_loop,
                                run_mesh_scan, sharded_sketch_avg_desk)
from repro.models import ModelConfig, init_params
from repro.models.sharding import use_mesh

ON_8 = jax.device_count() >= 8
NEW_SHARD_MAP = hasattr(jax, "shard_map")   # partial-manual needs jax>=0.6

needs8 = pytest.mark.skipif(not ON_8, reason="needs 8 forced CPU devices")

# both topologies run on both jax stacks: 0.4.x takes the cross_device vmap
# fallback (launch/train.py, DESIGN §9) instead of partial-manual shard_map
TOPOLOGIES = [
    pytest.param("cross_silo", id="cross_silo"),
    pytest.param("cross_device", id="cross_device"),
]

MODEL = ModelConfig(name="meshscan", arch_type="dense", num_layers=1,
                    d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                    vocab_size=64)


def _mk(topology, kind="countsketch"):
    """Mesh, config, sharded sampler for one (topology, compressor) case.

    One (2, 2, 2) pod/data/model mesh serves both topologies: cross_device
    clients = the 4 (pod, data) groups, cross_silo clients = the 2 pods
    (mb = 4 is data-sharded 2-way there)."""
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = SAFLConfig(sketch=SketchConfig(kind=kind, ratio=0.1, min_b=8),
                     server=AdaConfig(name="amsgrad", lr=0.01),
                     client_lr=0.5, local_steps=2)
    G = num_clients_of(mesh, topology)
    data = BigramLMData(LMDataConfig(vocab_size=64, seq_len=16,
                                     num_clients=G, alpha=0.05))
    smp = mesh_sampler(mesh, data.device_sampler(8, 2), topology)
    return mesh, cfg, smp


def _fresh(cfg):
    p = init_params(MODEL, jax.random.key(0))
    return p, init_safl(cfg, p)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# scanned == per-round, bitwise
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("topology", TOPOLOGIES)
@pytest.mark.parametrize("kind", ["countsketch", "none"])
def test_scan_matches_per_round_mesh_step_bitwise(topology, kind):
    """N scanned mesh rounds == N per-round jitted mesh steps, bit for bit:
    same fold_in(key, t) chain, same device-sampled sharded batches.
    kind="none" is the FedOPT raw-delta O(d) all-reduce inside the same
    scan layout."""
    mesh, cfg, smp = _mk(topology, kind)
    with use_mesh(mesh):
        step, _ = make_safl_train_step(MODEL, cfg, mesh, topology)
        key = jax.random.key(42)
        p1, o1, h1 = run_mesh_host_loop(step, smp, *_fresh(cfg), rounds=3,
                                        key=key, donate=False)
        # donate=True on the scan side also exercises the donated carries
        p2, o2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology=topology)
    assert h2["loss"].shape == (3,)
    assert np.isfinite(h2["loss"]).all()
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(o1, o2)


@needs8
def test_fedopt_scan_fn_matches_fedopt_step_bitwise():
    """The make_fedopt_scan_fn entry point (one chunk, donated carries)
    reproduces make_fedopt_train_step rounds exactly."""
    topology = "cross_silo"
    mesh, cfg, smp = _mk(topology, "countsketch")  # fedopt overrides sketch
    with use_mesh(mesh):
        step, _ = make_fedopt_train_step(MODEL, cfg, mesh, topology)
        key = jax.random.key(5)
        p1, o1, h1 = run_mesh_host_loop(step, smp, *_fresh(cfg), rounds=2,
                                        key=key, donate=False)
        chunk, _ = make_fedopt_scan_fn(MODEL, cfg, mesh, topology,
                                       sampler=smp, num_rounds=2)
        # key_data(key) aliases key's buffer and the chunk donates arg 3:
        # pass a fresh device copy so `key` survives
        kd = jnp.asarray(np.asarray(jax.random.key_data(key)))
        p2, o2, _, _, h2 = chunk(*_fresh(cfg), smp.init_state(), kd,
                                 jnp.asarray(0, jnp.int32))
    np.testing.assert_array_equal(h1["loss"], np.asarray(h2["loss"]))
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(o1, o2)


# ---------------------------------------------------------------------------
# chunking + donation
# ---------------------------------------------------------------------------

@needs8
def test_mesh_scan_chunk_split_invariance():
    """Chunked dispatch (2+2) is bit-identical to one 4-round dispatch and
    the stitched on-device loss history matches."""
    mesh, cfg, smp = _mk("cross_silo")
    with use_mesh(mesh):
        key = jax.random.key(7)
        p1, o1, h1 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=4, key=key, topology="cross_silo")
        p2, o2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=4, key=key, topology="cross_silo",
                                   chunk_size=2)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(o1, o2)


@needs8
def test_mesh_scan_donation_safe():
    """chunk_size=1 rethreads every donated (params, opt, data_state, key)
    buffer through 3 separate dispatches: an aliasing bug (donated buffer
    read after donation) crashes here.  on_chunk must observe progress."""
    mesh, cfg, smp = _mk("cross_silo")
    seen = []
    with use_mesh(mesh):
        p0, _ = _fresh(cfg)
        p, o, h = run_mesh_scan(
            MODEL, cfg, mesh, smp, *_fresh(cfg), rounds=3,
            key=jax.random.key(0), topology="cross_silo", chunk_size=1,
            donate=True,
            on_chunk=lambda t, pp, oo, hh: seen.append((t, hh["loss"].shape)))
    assert seen == [(1, (1,)), (2, (1,)), (3, (1,))]
    assert np.isfinite(h["loss"]).all()
    # params actually moved (the donated carry is not a stale alias)
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p)))
    assert moved


# ---------------------------------------------------------------------------
# plan-routed shard-local sketch == per-leaf reference
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("kind", ["countsketch", "srht", "gaussian"])
def test_sharded_sketch_plan_route_matches_per_leaf(kind):
    """The packed-plan route inside shard_map (operator derived once, one
    fused pass, ONE (G_loc, b_total) pmean) produces exactly the per-leaf
    reference loop's values -- same per-leaf fold_in chain."""
    topology = "cross_silo"
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    skcfg = SketchConfig(kind=kind, ratio=0.1, min_b=8)
    with use_mesh(mesh):
        abstract, pspecs = _mesh_pspecs(MODEL, topology)
        plan = make_sharded_packing_plan(skcfg, abstract, pspecs,
                                         dict(mesh.shape))
        params = init_params(MODEL, jax.random.key(0))
        G = num_clients_of(mesh, topology)
        deltas = jax.tree.map(
            lambda p: jax.random.normal(jax.random.key(9),
                                        (G,) + p.shape, jnp.float32), params)
        key = jax.random.key(3)
        ref = jax.jit(lambda d, k: sharded_sketch_avg_desk(
            mesh, skcfg, pspecs, d, k, topology))(deltas, key)
        pkd = jax.jit(lambda d, k: sharded_sketch_avg_desk(
            mesh, skcfg, pspecs, d, k, topology, plan=plan))(deltas, key)
    _assert_trees_equal(ref, pkd)


# ---------------------------------------------------------------------------
# streamed shard-local sketch fold (DESIGN §12, ISSUE 9)
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("mb", [1, 2])
def test_mesh_microbatch_streamed_fold_matches(mb):
    """With G_loc = 3 client rows per pod, microbatch=1/2 folds the
    shard-local sketch stage over chunks (mb=2 leaves a masked tail row)
    and reduces ONE (b_total,) partial sum + scalar weight over the client
    axes -- the result matches the materialized (G_loc, b_total) payload
    path up to float summation order, masked and unmasked."""
    topology = "cross_silo"
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    skcfg = SketchConfig(kind="countsketch", ratio=0.1, min_b=8)
    with use_mesh(mesh):
        abstract, pspecs = _mesh_pspecs(MODEL, topology)
        plan = make_sharded_packing_plan(skcfg, abstract, pspecs,
                                         dict(mesh.shape))
        params = init_params(MODEL, jax.random.key(0))
        G = 6                        # 3 rows per pod: the fold is exercised
        deltas = jax.tree.map(
            lambda p: jax.random.normal(jax.random.key(9),
                                        (G,) + p.shape, jnp.float32), params)
        key = jax.random.key(3)
        ref = jax.jit(lambda d, k: sharded_sketch_avg_desk(
            mesh, skcfg, pspecs, d, k, topology, plan=plan))(deltas, key)
        got = jax.jit(lambda d, k: sharded_sketch_avg_desk(
            mesh, skcfg, pspecs, d, k, topology, plan=plan,
            microbatch=mb))(deltas, key)
        mask = jnp.array([1., 0., 1., 1., 0., 1.])
        refm = jax.jit(lambda d, k: sharded_sketch_avg_desk(
            mesh, skcfg, pspecs, d, k, topology, plan=plan,
            part_mask=mask))(deltas, key)
        gotm = jax.jit(lambda d, k: sharded_sketch_avg_desk(
            mesh, skcfg, pspecs, d, k, topology, plan=plan, part_mask=mask,
            microbatch=mb))(deltas, key)
    for a, b in ((ref, got), (refm, gotm)):
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=3e-5, atol=3e-6)


@needs8
def test_mesh_microbatch_ge_gloc_is_bitwise_pinned():
    """microbatch >= the shard-local cohort resolves to the materialized
    program: run_mesh_scan trajectories are bit-identical to microbatch
    absent (the mesh analogue of the single-host routing pin)."""
    mesh, cfg, smp = _mk("cross_silo")
    with use_mesh(mesh):
        key = jax.random.key(42)
        p1, o1, h1 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology="cross_silo")
        p2, o2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology="cross_silo",
                                   microbatch=64)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(o1, o2)


@needs8
def test_mesh_microbatch_hook_combinations_raise():
    """Streaming folds the payload before per-client rows exist: the
    staleness buffer and the fault/sentinel guard (materialized-row
    consumers) refuse to combine with it, as does fedopt (no sketch)."""
    mesh, cfg, smp = _mk("cross_silo")
    with use_mesh(mesh):
        with pytest.raises(NotImplementedError, match="microbatch"):
            train_mod._make_round_core(
                MODEL, cfg, mesh, "cross_silo", buffer=AsyncConfig(),
                microbatch=1)
        with pytest.raises(NotImplementedError, match="microbatch"):
            train_mod._make_round_core(
                MODEL, cfg, mesh, "cross_silo",
                sentinel=SentinelConfig(norm_mult=0.0), microbatch=1)
        with pytest.raises(ValueError, match="sketch"):
            train_mod._make_round_core(
                MODEL, train_mod._fedopt_cfg(cfg), mesh, "cross_silo",
                microbatch=1)


# ---------------------------------------------------------------------------
# quantized payload codec on the mesh driver (DESIGN §13)
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("topology", TOPOLOGIES)
def test_mesh_codec_none_is_bitwise_pinned(topology):
    """``codec=None`` must route at Python level: an explicit None equals
    the hookless mesh scan bit for bit (no traced neutral quantize)."""
    mesh, cfg, smp = _mk(topology)
    key = jax.random.key(42)
    with use_mesh(mesh):
        p1, o1, h1 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology=topology)
        p2, o2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology=topology,
                                   codec=None)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(o1, o2)


@needs8
@pytest.mark.parametrize("topology", TOPOLOGIES)
def test_mesh_codec_runs_finite_with_static_measured_bits(topology):
    """The shard-sum codec (quantize-before-reduce) trains finite and
    reports the static measured uplink: payload_bits(b_total) per client
    shard, every shard transmitting its partial sum each round."""
    mesh, cfg, smp = _mk(topology)
    codec = CodecConfig(bits=8, error_feedback=False)
    key = jax.random.key(42)
    with use_mesh(mesh):
        p, o, h = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                rounds=3, key=key, topology=topology,
                                codec=codec)
        _, _, plan = train_mod._mesh_plan(MODEL, cfg, mesh, topology)
    n_shards = 1
    for ax in train_mod.client_axes_of(mesh, topology):
        n_shards *= mesh.shape[ax]
    assert np.isfinite(np.asarray(h["loss"])).all()
    np.testing.assert_array_equal(
        np.asarray(h["uplink_bits"]),
        float(codec.payload_bits(plan.b_total) * n_shards))
    # quantized trajectory is its own family: it moved vs the exact one
    _, _, h0 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg), rounds=3,
                             key=key, topology=topology)
    assert not np.array_equal(np.asarray(h["loss"]), np.asarray(h0["loss"]))


@needs8
def test_mesh_codec_hook_combinations_raise():
    """The mesh codec quantizes shard-local partial sums: materialized-row
    consumers (buffer, guard), telemetry, fedopt, and per-client error
    feedback all refuse to combine with it (DESIGN §13 hook matrix)."""
    from repro.obs import Telemetry
    mesh, cfg, smp = _mk("cross_silo")
    codec = CodecConfig(bits=8, error_feedback=False)
    with use_mesh(mesh):
        with pytest.raises(NotImplementedError, match="codec"):
            train_mod._make_round_core(MODEL, cfg, mesh, "cross_silo",
                                       buffer=AsyncConfig(), codec=codec)
        with pytest.raises(NotImplementedError, match="codec"):
            train_mod._make_round_core(
                MODEL, cfg, mesh, "cross_silo",
                sentinel=SentinelConfig(norm_mult=0.0), codec=codec)
        with pytest.raises(ValueError, match="telemetry"):
            train_mod._make_round_core(MODEL, cfg, mesh, "cross_silo",
                                       telemetry=Telemetry(), codec=codec)
        with pytest.raises(ValueError, match="no sketch payload"):
            train_mod._make_round_core(MODEL, train_mod._fedopt_cfg(cfg),
                                       mesh, "cross_silo", codec=codec)
        with pytest.raises(ValueError, match="error feedback"):
            train_mod._make_round_core(MODEL, cfg, mesh, "cross_silo",
                                       codec=CodecConfig(bits=8))


@needs8
def test_mesh_microbatch_codec_matches_materialized_codec():
    """Streaming the shard-local fold and quantizing the same partial sum:
    microbatch >= G_loc with a codec equals the materialized codec round
    bitwise (same quantizer input, same flat-shard-index RNG)."""
    mesh, cfg, smp = _mk("cross_silo")
    codec = CodecConfig(bits=8, error_feedback=False)
    key = jax.random.key(42)
    with use_mesh(mesh):
        p1, o1, h1 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology="cross_silo",
                                   codec=codec)
        p2, o2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology="cross_silo",
                                   codec=codec, microbatch=64)
    np.testing.assert_array_equal(np.asarray(h1["loss"]),
                                  np.asarray(h2["loss"]))
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(o1, o2)


# ---------------------------------------------------------------------------
# repro.fed hooks on the mesh driver (ISSUE 5, DESIGN §9)
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("topology", TOPOLOGIES)
def test_mesh_hooks_allones_mask_and_delay0_buffer_pin_bitwise(topology):
    """The ISSUE 5 acceptance pin: ``run_mesh_scan(participation=...,
    buffer=...)`` with an all-ones mask and a delay=0 buffer reproduces the
    PR-4 hookless mesh trajectories bit for bit -- the masked cohort mean
    lowers to the unmasked pmean and the d > 0 arrival groups constant-fold
    away."""
    mesh, cfg, smp = _mk(topology)
    G = num_clients_of(mesh, topology)
    key = jax.random.key(42)
    with use_mesh(mesh):
        p0, o0, h0 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology=topology)
        p1, o1, h1 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology=topology,
                                   participation=FullParticipation(G))
        acfg = AsyncConfig(max_delay=0, delay="zero")
        p, _ = _fresh(cfg)
        st = init_mesh_async_state(MODEL, cfg, acfg, mesh, p, topology)
        p2, s2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, p, st, rounds=3,
                                   key=key, topology=topology, buffer=acfg,
                                   participation=FullParticipation(G))
    np.testing.assert_array_equal(h0["loss"], h1["loss"])
    _assert_trees_equal((p0, o0), (p1, o1))
    np.testing.assert_array_equal(h0["loss"], h2["loss"])
    _assert_trees_equal((p0, o0), (p2, s2["opt"]))


@needs8
@pytest.mark.parametrize("topology", TOPOLOGIES)
def test_mesh_masked_scan_matches_hooked_host_loop_bitwise(topology):
    """Partial cohorts on the mesh: the scanned driver and the hooked
    per-round step (same policy, base key + round index calling convention)
    agree bitwise, and the cohort actually changes the trajectory vs full
    participation."""
    mesh, cfg, smp = _mk(topology)
    G = num_clients_of(mesh, topology)
    pol = UniformParticipation(G, frac=0.5, seed=7)
    key = jax.random.key(11)
    with use_mesh(mesh):
        step, _ = make_safl_train_step(MODEL, cfg, mesh, topology,
                                       participation=pol)
        p1, o1, h1 = run_mesh_host_loop(step, smp, *_fresh(cfg), rounds=3,
                                        key=key, donate=False,
                                        participation=pol)
        p2, o2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology=topology,
                                   participation=pol)
        _, _, h0 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                 rounds=3, key=key, topology=topology)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal((p1, o1), (p2, o2))
    assert not np.array_equal(h0["loss"], h2["loss"])


@needs8
def test_mesh_async_buffer_scan_matches_hooked_host_loop_bitwise():
    """Real staleness on the mesh (stagger delays over a 3-deep ring): the
    ring buffer lives in the donated scan carry and per-generation
    desketching inside shard_map reproduces the hooked per-round loop
    bitwise; delayed arrivals change the trajectory."""
    topology = "cross_silo"
    mesh, cfg, smp = _mk(topology)
    acfg = AsyncConfig(max_delay=2, delay="stagger", staleness_alpha=0.5)
    key = jax.random.key(3)

    def fresh_async():
        p, _ = _fresh(cfg)
        return p, init_mesh_async_state(MODEL, cfg, acfg, mesh, p, topology)

    with use_mesh(mesh):
        step, _ = make_safl_train_step(MODEL, cfg, mesh, topology,
                                       buffer=acfg)
        p1, s1, h1 = run_mesh_host_loop(step, smp, *fresh_async(), rounds=4,
                                        key=key, donate=False, buffer=acfg)
        p2, s2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, *fresh_async(),
                                   rounds=4, key=key, topology=topology,
                                   buffer=acfg)
        _, _, h0 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                 rounds=4, key=key, topology=topology)
    assert np.isfinite(h2["loss"]).all()
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal((p1, s1), (p2, s2))
    assert not np.array_equal(h0["loss"], h2["loss"])


@needs8
def test_mesh_masked_scan_chunk_split_invariance():
    """Chunked masked+buffered dispatch == one dispatch, bitwise: cohorts
    and delays are pure functions of the absolute round index, and the ring
    buffer rethreads through the donated carry across dispatches."""
    topology = "cross_silo"
    mesh, cfg, smp = _mk(topology)
    G = num_clients_of(mesh, topology)
    pol = UniformParticipation(G, frac=0.5, seed=5)
    acfg = AsyncConfig(max_delay=1, delay="stagger")
    key = jax.random.key(9)

    def run(chunk_size):
        p, _ = _fresh(cfg)
        st = init_mesh_async_state(MODEL, cfg, acfg, mesh, p, topology)
        return run_mesh_scan(MODEL, cfg, mesh, smp, p, st, rounds=4,
                             key=key, topology=topology, participation=pol,
                             buffer=acfg, chunk_size=chunk_size)

    with use_mesh(mesh):
        p1, s1, h1 = run(0)
        p2, s2, h2 = run(2)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal((p1, s1), (p2, s2))


@needs8
def test_mesh_cohort_of_one():
    """Edge case: a single-client cohort on a (G, K) mesh -- the masked
    denominator is 1, the trajectory stays finite, and FixedCohort selects
    the same client every round (deterministic trajectory across runs)."""
    topology = "cross_device"
    mesh, cfg, smp = _mk(topology)
    G = num_clients_of(mesh, topology)
    pol = FixedCohort(G, clients=(1,))
    key = jax.random.key(21)
    with use_mesh(mesh):
        p1, o1, h1 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology=topology,
                                   participation=pol)
        p2, o2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology=topology,
                                   participation=pol)
    assert np.isfinite(h1["loss"]).all()
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal((p1, o1), (p2, o2))


@needs8
def test_mesh_importance_uniform_probs_pins_to_uniform_policy():
    """ImportanceParticipation's weighted dict masks ride the mesh masked
    aggregation (static Horvitz-Thompson denominator inside the shard_map);
    with uniform probs the tilt is the identity and the trajectory pins
    bitwise to UniformParticipation."""
    topology = "cross_silo"
    mesh, cfg, smp = _mk(topology)
    G = num_clients_of(mesh, topology)
    key = jax.random.key(13)
    uni = UniformParticipation(G, frac=0.5, seed=3)
    imp = ImportanceParticipation(G, probs=(1.0 / G,) * G, frac=0.5, seed=3)
    with use_mesh(mesh):
        p1, o1, h1 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology=topology,
                                   participation=uni)
        p2, o2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology=topology,
                                   participation=imp)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal((p1, o1), (p2, o2))


@needs8
def test_mesh_buffer_rejects_weighted_masks():
    """The mesh staleness buffer stores 0/1 cohort masks per generation;
    an importance-sampling policy's weighted dict mask must be rejected at
    trace time with a clear error, not silently mis-aggregated."""
    topology = "cross_silo"
    mesh, cfg, smp = _mk(topology)
    G = num_clients_of(mesh, topology)
    imp = ImportanceParticipation(G, probs=(1.0 / G,) * G, frac=0.5, seed=3)
    acfg = AsyncConfig(max_delay=1, delay="stagger")
    with use_mesh(mesh):
        p, _ = _fresh(cfg)
        st = init_mesh_async_state(MODEL, cfg, acfg, mesh, p, topology)
        with pytest.raises(TypeError, match="weighted.*masks"):
            run_mesh_scan(MODEL, cfg, mesh, smp, p, st, rounds=2,
                          key=jax.random.key(0), topology=topology,
                          participation=imp, buffer=acfg)


@needs8
def test_mesh_buffer_guards():
    """Build-time guards: fedopt (sketch.kind='none') cannot ride the
    sketch-space buffer, and a policy built for the wrong client count is
    rejected before any tracing."""
    topology = "cross_silo"
    mesh, cfg, smp = _mk(topology)
    acfg = AsyncConfig(max_delay=1)
    with use_mesh(mesh):
        p, o = _fresh(cfg)
        with pytest.raises(ValueError, match="sketch space"):
            make_fedopt_scan_fn(MODEL, cfg, mesh, topology, sampler=smp,
                                num_rounds=2, buffer=acfg)
        with pytest.raises(ValueError, match="num_clients"):
            run_mesh_scan(MODEL, cfg, mesh, smp, p, o, rounds=2,
                          key=jax.random.key(0), topology=topology,
                          participation=UniformParticipation(16, frac=0.5))


@needs8
@pytest.mark.skipif(not NEW_SHARD_MAP,
                    reason="the shard_map side of the parity pair needs "
                           "jax>=0.6 (0.4.x always takes the fallback)")
def test_vmap_fallback_matches_shard_map():
    """The jax-0.4.x cross_device client-delta fallback (vmap over the
    client axis instead of partial-manual shard_map) is bitwise-identical
    to the shard_map formulation -- asserted on the new stack, where both
    compile.  This is what justifies running the whole mesh suite on both
    stacks (DESIGN §9)."""
    topology = "cross_device"
    mesh, cfg, smp = _mk(topology)
    key = jax.random.key(42)
    with use_mesh(mesh):
        p1, o1, h1 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=2, key=key, topology=topology)
        train_mod._FORCE_VMAP_CLIENT_DELTAS = True
        try:
            p2, o2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                       rounds=2, key=key, topology=topology)
        finally:
            train_mod._FORCE_VMAP_CLIENT_DELTAS = False
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal((p1, o1), (p2, o2))


# ---------------------------------------------------------------------------
# faults + sentinels on the mesh (ISSUE 7, DESIGN §10)
# ---------------------------------------------------------------------------

def _fault_row(code, G, client=1):
    return tuple(code if c == client else FAULT_OK for c in range(G))


@needs8
@pytest.mark.parametrize("topology", TOPOLOGIES)
def test_mesh_neutral_faults_bitwise(topology):
    """A neutral fault policy (all rates 0) on the mesh scan == the
    hookless PR-4 trajectory, bit for bit -- the fault spec multiplies the
    replicated weight vector by all-ones arrivals and the payload by 1.0,
    and the guarded aggregation still pays exactly one payload psum."""
    mesh, cfg, smp = _mk(topology)
    G = num_clients_of(mesh, topology)
    key = jax.random.key(7)
    with use_mesh(mesh):
        p1, o1, h1 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology=topology)
        p2, o2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology=topology,
                                   faults=FaultConfig(num_clients=G))
    _assert_trees_equal((p1, o1), (p2, o2))
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    assert np.asarray(h2["n_dropped"]).sum() == 0


@needs8
@pytest.mark.parametrize("topology", TOPOLOGIES)
def test_mesh_nan_equals_drop_bitwise(topology):
    """Sentinel rejection of a NaN-corrupted client == dropping that
    client, bitwise, on the mesh: per-client finite verdicts are made
    globally consistent by one (G,)-stats psum over ALL mesh axes (model
    axes combine chunks of a row, client axes merge disjoint rows), then
    folded into the same replicated weight vector a dropout uses."""
    mesh, cfg, smp = _mk(topology)
    G = num_clients_of(mesh, topology)
    key = jax.random.key(7)
    sent = SentinelConfig(norm_mult=10.0)
    with use_mesh(mesh):
        p1, o1, h1 = run_mesh_scan(
            MODEL, cfg, mesh, smp, *_fresh(cfg), rounds=3, key=key,
            topology=topology, sentinel=sent,
            faults=FaultTable(codes=(_fault_row(FAULT_NAN, G),) * 2))
        p2, o2, h2 = run_mesh_scan(
            MODEL, cfg, mesh, smp, *_fresh(cfg), rounds=3, key=key,
            topology=topology, sentinel=sent,
            faults=FaultTable(codes=(_fault_row(FAULT_DROP, G),) * 2))
    _assert_trees_equal((p1, o1), (p2, o2))
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    assert np.isfinite(h1["loss"]).all()
    for x in jax.tree.leaves(p1):
        assert np.isfinite(np.asarray(x)).all()
    assert np.asarray(h1["n_rejected"]).sum() == 2
    assert np.asarray(h2["n_dropped"]).sum() == 2


@needs8
def test_mesh_byzantine_rejected_by_norm_sentinel():
    """A Byzantine-scaled payload passes the finite check but its sketch
    norm -- summed across model-parallel chunks by the same stats psum --
    trips the median rule; the run matches the drop-masked twin bitwise."""
    topology = "cross_silo"
    mesh, cfg, smp = _mk(topology)
    G = num_clients_of(mesh, topology)
    key = jax.random.key(7)
    sent = SentinelConfig(norm_mult=10.0)
    with use_mesh(mesh):
        p1, o1, h1 = run_mesh_scan(
            MODEL, cfg, mesh, smp, *_fresh(cfg), rounds=3, key=key,
            topology=topology, sentinel=sent,
            faults=FaultTable(codes=(_fault_row(FAULT_BYZ, G),) * 2,
                              byzantine_scale=1e4))
        p2, o2, h2 = run_mesh_scan(
            MODEL, cfg, mesh, smp, *_fresh(cfg), rounds=3, key=key,
            topology=topology, sentinel=sent,
            faults=FaultTable(codes=(_fault_row(FAULT_DROP, G),) * 2))
    _assert_trees_equal((p1, o1), (p2, o2))
    assert np.asarray(h1["n_rejected"]).sum() == 2


@needs8
def test_mesh_buffered_guarded_nan_equals_drop():
    """Through the mesh ring buffer: payloads are vetted BEFORE the push,
    so a NaN generation never re-emits at later pops and the trajectory
    (params/opt/loss) matches the drop twin bitwise.  Ring CONTENTS may
    differ where weights are 0 (zeroed vs honest row), so the ring is
    checked for finiteness, not equality."""
    topology = "cross_silo"
    mesh, cfg, smp = _mk(topology)
    G = num_clients_of(mesh, topology)
    acfg = AsyncConfig(max_delay=2, delay="stagger", staleness_alpha=0.5)
    key = jax.random.key(3)
    sent = SentinelConfig(norm_mult=10.0)

    def fresh_async():
        p, _ = _fresh(cfg)
        return p, init_mesh_async_state(MODEL, cfg, acfg, mesh, p, topology)

    with use_mesh(mesh):
        p1, s1, h1 = run_mesh_scan(
            MODEL, cfg, mesh, smp, *fresh_async(), rounds=4, key=key,
            topology=topology, buffer=acfg, sentinel=sent,
            faults=FaultTable(codes=(_fault_row(FAULT_NAN, G),) * 2))
        p2, s2, h2 = run_mesh_scan(
            MODEL, cfg, mesh, smp, *fresh_async(), rounds=4, key=key,
            topology=topology, buffer=acfg, sentinel=sent,
            faults=FaultTable(codes=(_fault_row(FAULT_DROP, G),) * 2))
    _assert_trees_equal((p1, s1["opt"]), (p2, s2["opt"]))
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    assert np.isfinite(np.asarray(s1["buf"])).all()
    assert np.asarray(h1["n_rejected"]).sum() == 2


@needs8
def test_mesh_fault_hook_guards():
    """Build-time guards: sketch-space faults/sentinels cannot ride the
    fedopt (sketch.kind='none') route, and a fault policy built for the
    wrong client count is rejected before tracing."""
    topology = "cross_silo"
    mesh, cfg, smp = _mk(topology)
    cfg_none = _mk(topology, "none")[1]
    G = num_clients_of(mesh, topology)
    with use_mesh(mesh):
        p, o = _fresh(cfg)
        with pytest.raises(ValueError, match="sketch"):
            run_mesh_scan(MODEL, cfg_none, mesh, smp, p, o, rounds=2,
                          key=jax.random.key(0), topology=topology,
                          faults=FaultConfig(num_clients=G))
        with pytest.raises(ValueError, match="clients"):
            run_mesh_scan(MODEL, cfg, mesh, smp, p, o, rounds=2,
                          key=jax.random.key(0), topology=topology,
                          faults=FaultConfig(num_clients=16))


# ---------------------------------------------------------------------------
# observability (DESIGN §11): probes + streamed shards on the mesh driver
# ---------------------------------------------------------------------------

@needs8
def test_mesh_telemetry_probes_and_stream(tmp_path):
    """Telemetry on the mesh scan: the Δ̄-based probes (computed OUTSIDE
    the sketch shard_map) land in the history with the full-cohort count,
    and attaching a stream= writer is pure host-side I/O -- params bitwise
    unchanged, shard rows equal to the in-memory history value-for-value."""
    import glob
    import json

    from repro.obs import ShardWriter, Telemetry

    mesh, cfg, smp = _mk("cross_device")
    with use_mesh(mesh):
        pA, oA, hA = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=4, key=jax.random.key(3),
                                   chunk_size=2, telemetry=Telemetry())
        stream = ShardWriter(str(tmp_path / "obs"))
        pB, oB, hB = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=4, key=jax.random.key(3),
                                   chunk_size=2, telemetry=Telemetry(),
                                   stream=stream)
    _assert_trees_equal(pA, pB)
    _assert_trees_equal(oA, oB)
    assert hB == {}                   # streamed: the shards are the record
    G = num_clients_of(mesh, "cross_device")
    np.testing.assert_array_equal(hA["cohort"], np.full(4, float(G)))
    assert np.isfinite(hA["residual"]).all() and (hA["residual"] >= 0).all()
    assert (hA["delta_norm"] > 0).all() and (hA["m_norm"] > 0).all()
    rows = []
    for path in sorted(glob.glob(str(tmp_path / "obs" / "metrics-*.jsonl"))):
        with open(path) as f:
            rows += [json.loads(ln) for ln in f]
    assert [r["t"] for r in rows] == list(range(4))
    for i, row in enumerate(rows):
        assert set(row) - {"kind", "t"} == set(hA)
        for k in hA:
            assert row[k] == float(hA[k][i])


# ---------------------------------------------------------------------------
# single-device fallback: re-run this module on 8 forced CPU devices
# ---------------------------------------------------------------------------

@pytest.mark.skipif(ON_8, reason="already running on >= 8 devices")
@pytest.mark.skipif(os.environ.get("MESH_SCAN_NO_SUBPROCESS") == "1",
                    reason="suppressed: a dedicated 8-device step runs the "
                           "suite directly (ci.yml), or we ARE the "
                           "subprocess (re-entry guard)")
def test_mesh_scan_suite_on_8_forced_devices_subprocess():
    """Tier-1 coverage on a single-device session: run this module's direct
    tests in a subprocess with the 8-device host flag (which must never leak
    into the main test session, DESIGN §5)."""
    env = {"PYTHONPATH": "src", "PATH": os.environ.get("PATH",
                                                       "/usr/bin:/bin"),
           "HOME": os.environ.get("HOME", "/root"),
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           # the device-count flag only affects the CPU backend: pin it so a
           # GPU machine cannot land back on < 8 devices and recurse
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
           "MESH_SCAN_NO_SUBPROCESS": "1"}
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__)],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    tail = r.stdout[-3000:] + "\n" + r.stderr[-2000:]
    assert r.returncode == 0, tail
    assert " passed" in r.stdout, tail   # not everything skipped
