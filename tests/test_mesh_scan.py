"""Multi-pod scanned mesh driver (launch/train.py, DESIGN §8).

Pins the ISSUE 4 contracts on an 8-forced-CPU-device host mesh:

  * scanned mesh rounds (``run_mesh_scan``: one ``lax.scan`` OUTSIDE the
    shard_map round, donated (params, opt, data_state, key) carries) are
    bit-identical to per-round jitted mesh steps (``run_mesh_host_loop``)
    for safl AND fedopt, on cross_device and cross_silo topologies;
  * chunk-split invariance: chunked dispatch == one-dispatch, bitwise;
  * donation safety: chunk_size=1 rethreads every donated carry across
    dispatches without aliasing crashes;
  * the plan-routed shard-local sketch (``make_sharded_packing_plan`` +
    packed sk/desk inside shard_map) equals the per-leaf reference loop.

Device policy (DESIGN §5): the 8-device flag must NOT leak into the main
suite, so when this module is collected on a single-device session it
re-runs itself in a subprocess with
``--xla_force_host_platform_device_count=8`` (the mini-dry-run pattern);
CI additionally runs the direct tests in a dedicated 8-device job step.
cross_device cases need the jax>=0.6 stack -- partial-manual shard_map over
the client axes hard-crashes the XLA bundled with jax 0.4.x
(IsManualSubgroup CHECK; see tests/test_sharding_and_dryrun.py) -- while
cross_silo (vmapped client deltas + full-manual sketch shard_map) runs on
both stacks.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import AdaConfig
from repro.core.packed import make_sharded_packing_plan
from repro.core.safl import SAFLConfig, init_safl
from repro.core.sketch import SketchConfig
from repro.data import BigramLMData, LMDataConfig
from repro.launch.mesh import _mesh
from repro.launch.train import (_mesh_pspecs, make_fedopt_scan_fn,
                                make_fedopt_train_step, make_safl_train_step,
                                mesh_sampler, num_clients_of,
                                run_mesh_host_loop, run_mesh_scan,
                                sharded_sketch_avg_desk)
from repro.models import ModelConfig, init_params
from repro.models.sharding import use_mesh

ON_8 = jax.device_count() >= 8
NEW_SHARD_MAP = hasattr(jax, "shard_map")   # partial-manual needs jax>=0.6

needs8 = pytest.mark.skipif(not ON_8, reason="needs 8 forced CPU devices")

TOPOLOGIES = [
    pytest.param("cross_silo", id="cross_silo"),
    pytest.param("cross_device", id="cross_device",
                 marks=pytest.mark.skipif(
                     not NEW_SHARD_MAP,
                     reason="partial-manual shard_map hard-crashes the XLA "
                            "bundled with jax 0.4.x (IsManualSubgroup)")),
]

MODEL = ModelConfig(name="meshscan", arch_type="dense", num_layers=1,
                    d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                    vocab_size=64)


def _mk(topology, kind="countsketch"):
    """Mesh, config, sharded sampler for one (topology, compressor) case.

    One (2, 2, 2) pod/data/model mesh serves both topologies: cross_device
    clients = the 4 (pod, data) groups, cross_silo clients = the 2 pods
    (mb = 4 is data-sharded 2-way there)."""
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = SAFLConfig(sketch=SketchConfig(kind=kind, ratio=0.1, min_b=8),
                     server=AdaConfig(name="amsgrad", lr=0.01),
                     client_lr=0.5, local_steps=2)
    G = num_clients_of(mesh, topology)
    data = BigramLMData(LMDataConfig(vocab_size=64, seq_len=16,
                                     num_clients=G, alpha=0.05))
    smp = mesh_sampler(mesh, data.device_sampler(8, 2), topology)
    return mesh, cfg, smp


def _fresh(cfg):
    p = init_params(MODEL, jax.random.key(0))
    return p, init_safl(cfg, p)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# scanned == per-round, bitwise
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("topology", TOPOLOGIES)
@pytest.mark.parametrize("kind", ["countsketch", "none"])
def test_scan_matches_per_round_mesh_step_bitwise(topology, kind):
    """N scanned mesh rounds == N per-round jitted mesh steps, bit for bit:
    same fold_in(key, t) chain, same device-sampled sharded batches.
    kind="none" is the FedOPT raw-delta O(d) all-reduce inside the same
    scan layout."""
    mesh, cfg, smp = _mk(topology, kind)
    with use_mesh(mesh):
        step, _ = make_safl_train_step(MODEL, cfg, mesh, topology)
        key = jax.random.key(42)
        p1, o1, h1 = run_mesh_host_loop(step, smp, *_fresh(cfg), rounds=3,
                                        key=key, donate=False)
        # donate=True on the scan side also exercises the donated carries
        p2, o2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=3, key=key, topology=topology)
    assert h2["loss"].shape == (3,)
    assert np.isfinite(h2["loss"]).all()
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(o1, o2)


@needs8
def test_fedopt_scan_fn_matches_fedopt_step_bitwise():
    """The make_fedopt_scan_fn entry point (one chunk, donated carries)
    reproduces make_fedopt_train_step rounds exactly."""
    topology = "cross_silo"
    mesh, cfg, smp = _mk(topology, "countsketch")  # fedopt overrides sketch
    with use_mesh(mesh):
        step, _ = make_fedopt_train_step(MODEL, cfg, mesh, topology)
        key = jax.random.key(5)
        p1, o1, h1 = run_mesh_host_loop(step, smp, *_fresh(cfg), rounds=2,
                                        key=key, donate=False)
        chunk, _ = make_fedopt_scan_fn(MODEL, cfg, mesh, topology,
                                       sampler=smp, num_rounds=2)
        # key_data(key) aliases key's buffer and the chunk donates arg 3:
        # pass a fresh device copy so `key` survives
        kd = jnp.asarray(np.asarray(jax.random.key_data(key)))
        p2, o2, _, _, h2 = chunk(*_fresh(cfg), smp.init_state(), kd,
                                 jnp.asarray(0, jnp.int32))
    np.testing.assert_array_equal(h1["loss"], np.asarray(h2["loss"]))
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(o1, o2)


# ---------------------------------------------------------------------------
# chunking + donation
# ---------------------------------------------------------------------------

@needs8
def test_mesh_scan_chunk_split_invariance():
    """Chunked dispatch (2+2) is bit-identical to one 4-round dispatch and
    the stitched on-device loss history matches."""
    mesh, cfg, smp = _mk("cross_silo")
    with use_mesh(mesh):
        key = jax.random.key(7)
        p1, o1, h1 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=4, key=key, topology="cross_silo")
        p2, o2, h2 = run_mesh_scan(MODEL, cfg, mesh, smp, *_fresh(cfg),
                                   rounds=4, key=key, topology="cross_silo",
                                   chunk_size=2)
    np.testing.assert_array_equal(h1["loss"], h2["loss"])
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(o1, o2)


@needs8
def test_mesh_scan_donation_safe():
    """chunk_size=1 rethreads every donated (params, opt, data_state, key)
    buffer through 3 separate dispatches: an aliasing bug (donated buffer
    read after donation) crashes here.  on_chunk must observe progress."""
    mesh, cfg, smp = _mk("cross_silo")
    seen = []
    with use_mesh(mesh):
        p0, _ = _fresh(cfg)
        p, o, h = run_mesh_scan(
            MODEL, cfg, mesh, smp, *_fresh(cfg), rounds=3,
            key=jax.random.key(0), topology="cross_silo", chunk_size=1,
            donate=True,
            on_chunk=lambda t, pp, oo, hh: seen.append((t, hh["loss"].shape)))
    assert seen == [(1, (1,)), (2, (1,)), (3, (1,))]
    assert np.isfinite(h["loss"]).all()
    # params actually moved (the donated carry is not a stale alias)
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p)))
    assert moved


# ---------------------------------------------------------------------------
# plan-routed shard-local sketch == per-leaf reference
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("kind", ["countsketch", "srht", "gaussian"])
def test_sharded_sketch_plan_route_matches_per_leaf(kind):
    """The packed-plan route inside shard_map (operator derived once, one
    fused pass, ONE (G_loc, b_total) pmean) produces exactly the per-leaf
    reference loop's values -- same per-leaf fold_in chain."""
    topology = "cross_silo"
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    skcfg = SketchConfig(kind=kind, ratio=0.1, min_b=8)
    with use_mesh(mesh):
        abstract, pspecs = _mesh_pspecs(MODEL, topology)
        plan = make_sharded_packing_plan(skcfg, abstract, pspecs,
                                         dict(mesh.shape))
        params = init_params(MODEL, jax.random.key(0))
        G = num_clients_of(mesh, topology)
        deltas = jax.tree.map(
            lambda p: jax.random.normal(jax.random.key(9),
                                        (G,) + p.shape, jnp.float32), params)
        key = jax.random.key(3)
        ref = jax.jit(lambda d, k: sharded_sketch_avg_desk(
            mesh, skcfg, pspecs, d, k, topology))(deltas, key)
        pkd = jax.jit(lambda d, k: sharded_sketch_avg_desk(
            mesh, skcfg, pspecs, d, k, topology, plan=plan))(deltas, key)
    _assert_trees_equal(ref, pkd)


# ---------------------------------------------------------------------------
# single-device fallback: re-run this module on 8 forced CPU devices
# ---------------------------------------------------------------------------

@pytest.mark.skipif(ON_8, reason="already running on >= 8 devices")
@pytest.mark.skipif(os.environ.get("MESH_SCAN_NO_SUBPROCESS") == "1",
                    reason="suppressed: a dedicated 8-device step runs the "
                           "suite directly (ci.yml), or we ARE the "
                           "subprocess (re-entry guard)")
def test_mesh_scan_suite_on_8_forced_devices_subprocess():
    """Tier-1 coverage on a single-device session: run this module's direct
    tests in a subprocess with the 8-device host flag (which must never leak
    into the main test session, DESIGN §5)."""
    env = {"PYTHONPATH": "src", "PATH": os.environ.get("PATH",
                                                       "/usr/bin:/bin"),
           "HOME": os.environ.get("HOME", "/root"),
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           # the device-count flag only affects the CPU backend: pin it so a
           # GPU machine cannot land back on < 8 devices and recurse
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
           "MESH_SCAN_NO_SUBPROCESS": "1"}
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__)],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    tail = r.stdout[-3000:] + "\n" + r.stderr[-2000:]
    assert r.returncode == 0, tail
    assert " passed" in r.stdout, tail   # not everything skipped
