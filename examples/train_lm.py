"""End-to-end driver: train a ~25M-parameter LM with SAFL for a few hundred
rounds on synthetic federated data, with cosine LR, checkpointing, and an
uncompressed FedOPT reference (deliverable b).

    PYTHONPATH=src python examples/train_lm.py [--rounds 200] [--big]

--big uses a ~100M model (BERT-scale, the paper's language setup).
"""
import argparse
import functools
import os

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.adaptive import AdaConfig
from repro.core.safl import SAFLConfig, fedopt_round, init_safl, safl_round
from repro.core.sketch import SketchConfig
from repro.data import BigramLMData, LMDataConfig
from repro.models import ModelConfig, init_params, loss_fn
from repro.optim import cosine

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=200)
ap.add_argument("--big", action="store_true")
ap.add_argument("--ratio", type=float, default=0.02)
ap.add_argument("--ckpt", default="/tmp/safl_lm")
ap.add_argument("--fedopt", action="store_true", help="run the uncompressed"
                " reference instead of SAFL")
args = ap.parse_args()

if args.big:  # ~100M (paper's BERT scale)
    model = ModelConfig(name="lm100m", arch_type="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=12,
                        d_ff=3072, vocab_size=8192)
else:         # ~25M -- trains a few hundred rounds in CPU-minutes
    model = ModelConfig(name="lm25m", arch_type="dense", num_layers=6,
                        d_model=384, num_heads=6, num_kv_heads=6,
                        d_ff=1536, vocab_size=4096)

safl = SAFLConfig(
    sketch=SketchConfig(kind="countsketch", ratio=args.ratio, min_b=64),
    server=AdaConfig(name="amsgrad", lr=0.01),
    client_lr=0.5, local_steps=2)

data = BigramLMData(LMDataConfig(vocab_size=model.vocab_size, seq_len=64,
                                 num_clients=5, heterogeneity=0.3,
                                 alpha=0.02))
params = init_params(model, jax.random.key(0))
opt = init_safl(safl, params)
loss = lambda p, b: loss_fn(model, p, b)
round_fn = fedopt_round if args.fedopt else safl_round
step = jax.jit(functools.partial(round_fn, safl, loss))
sched = cosine(args.rounds, warmup=10)

n = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
print(f"{'FedOPT' if args.fedopt else 'SAFL'} on {n/1e6:.1f}M params, "
      f"sketch ratio {args.ratio}")
for t in range(args.rounds):
    batch = data.round_batch(batch_per_client=8, local_steps=2, seed=t)
    params, opt, m = step(params, opt, batch, jax.random.key(t),
                          lr_scale=sched(jnp.asarray(t)))
    if t % 20 == 0 or t == args.rounds - 1:
        print(f"round {t:4d}  loss {float(m['loss']):.4f}")
    if t and t % 100 == 0:
        save_checkpoint(args.ckpt, {"params": params, "opt": opt}, step=t)
save_checkpoint(args.ckpt, {"params": params, "opt": opt}, step=args.rounds)
print("checkpoint saved to", args.ckpt + ".npz")
