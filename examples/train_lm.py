"""End-to-end driver: train a ~25M-parameter LM with SAFL for a few hundred
rounds on synthetic federated data, with cosine LR, checkpointing, and an
uncompressed FedOPT reference (deliverable b).

    PYTHONPATH=src python examples/train_lm.py [--rounds 200] [--big]

--big uses a ~100M model (BERT-scale, the paper's language setup).
--participation-frac 0.4 samples a 2-of-5 cohort per round (repro.fed);
--async-buffer 2 runs the FedBuff-style staleness buffer with client delays
up to 2 rounds.  Both ride the scanned driver's hooks and keep the
trajectory resumable: each chunk checkpoint stores the (t, key) cursor, and
because every per-round stream (data, cohorts, delays, sketch operators) is
a pure function of the absolute round index, a restart from the cursor
replays the identical trajectory.

Robustness (DESIGN.md §10): --faults 0.15 injects deterministic client
faults (dropout-after-compute / NaN payloads / Byzantine scaling, rate/3
each); --sentinel turns on the sketch-space payload sentinels that reject
the corrupted uplinks; --max-retries 3 wraps the run in the
checkpoint-rollback supervisor, which rolls a diverged span back to the
last good (t, key) cursor and re-runs it under a rekeyed fault stream,
printing the recovery log at exit.

Observability (DESIGN.md §11): --telemetry turns on the in-graph probes
(delta/update norms, desketch residual, moment norms, effective cohort)
and streams per-chunk JSONL metric shards + a run manifest into
--telemetry-out (default <ckpt>_obs), then prints a compact end-of-run
summary.  Render with ``python tools/obs_report.py <dir>``.  NOTE the
probes are extra scan outputs, so a --telemetry trajectory is its own
program family -- bit-comparable to other --telemetry runs, not to the
probe-free default (the fusion caveat DESIGN §11 documents).

Payload codec (DESIGN.md §13): --codec int8 / --codec 1bit quantizes the
packed sketch uplink with stochastic rounding + sketch-space error
feedback, and switches uplink_bits to the MEASURED encoded size
(per-row scale + mantissa bits, billed to the clients that actually
transmitted).  The EF memory rides in the scanned optimizer state, so
--resume round-trips it like any other carry leaf.
"""
import argparse
import functools
import os

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.adaptive import AdaConfig
from repro.core.packed import make_packing_plan
from repro.core.safl import SAFLConfig, fedopt_round, init_safl, safl_round
from repro.core.sketch import SketchConfig
from repro.data import BigramLMData, LMDataConfig
from repro.fed import AsyncConfig, CodecConfig, FaultConfig, \
    SentinelConfig, UniformParticipation, init_async_state, \
    init_codec_state, make_async_round
from repro.launch.driver import run_scan
from repro.launch.supervisor import SupervisorConfig, format_recovery_log, \
    run_supervised
from repro.models import ModelConfig, init_params, loss_fn
from repro.obs import ShardWriter, Telemetry, format_summary, write_manifest
from repro.optim import cosine

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=200)
ap.add_argument("--big", action="store_true")
ap.add_argument("--ratio", type=float, default=0.02)
ap.add_argument("--ckpt", default="/tmp/safl_lm")
ap.add_argument("--fedopt", action="store_true", help="run the uncompressed"
                " reference instead of SAFL")
ap.add_argument("--participation-frac", type=float, default=1.0,
                help="fraction of clients sampled per round (repro.fed "
                "uniform-without-replacement cohorts; 1.0 = all)")
ap.add_argument("--async-buffer", type=int, default=0, metavar="MAX_DELAY",
                help="run the FedBuff-style staleness buffer with client "
                "delays up to MAX_DELAY rounds (0 = synchronous)")
ap.add_argument("--faults", type=float, default=0.0, metavar="RATE",
                help="inject deterministic client faults at this total "
                "rate, split RATE/3 each across dropout-after-compute, "
                "NaN-corrupted payloads, and 1e3-scaled Byzantine payloads "
                "(repro.fed.faults; 0 = fault-free)")
ap.add_argument("--sentinel", action="store_true",
                help="enable the sketch-space payload sentinels: per-"
                "client finite checks + norm-outlier rejection folded "
                "into the aggregation mask (repro.fed.robust)")
ap.add_argument("--max-retries", type=int, default=0, metavar="N",
                help="wrap the run in the checkpoint-rollback supervisor "
                "with up to N rekeyed retries of a diverged span "
                "(launch/supervisor.py; 0 = unsupervised)")
ap.add_argument("--telemetry", action="store_true",
                help="enable the in-graph telemetry probes and stream "
                "per-chunk JSONL metric shards + a run manifest "
                "(repro.obs, DESIGN.md §11)")
ap.add_argument("--telemetry-out", default=None, metavar="PATH",
                help="run directory for the telemetry shards/manifest "
                "(default: <--ckpt>_obs)")
ap.add_argument("--codec", choices=["int8", "1bit"], default=None,
                help="quantize the packed sketch uplink with the payload "
                "codec (stochastic rounding + sketch-space error feedback, "
                "repro.fed.codec, DESIGN.md §13); uplink_bits becomes the "
                "measured encoded size")
ap.add_argument("--resume", action="store_true",
                help="restart from --ckpt's (t, key) cursor and resume the "
                "EXACT trajectory (pass the same model/algorithm flags): "
                "every per-round stream is a pure function of the absolute "
                "round index, so the resumed run is bit-identical to an "
                "uninterrupted one (tests/test_resume.py)")
args = ap.parse_args()

if args.big:  # ~100M (paper's BERT scale)
    model = ModelConfig(name="lm100m", arch_type="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=12,
                        d_ff=3072, vocab_size=8192)
else:         # ~25M -- trains a few hundred rounds in CPU-minutes
    model = ModelConfig(name="lm25m", arch_type="dense", num_layers=6,
                        d_model=384, num_heads=6, num_kv_heads=6,
                        d_ff=1536, vocab_size=4096)

safl = SAFLConfig(
    sketch=SketchConfig(kind="countsketch", ratio=args.ratio, min_b=64),
    server=AdaConfig(name="amsgrad", lr=0.01),
    client_lr=0.5, local_steps=2)

data = BigramLMData(LMDataConfig(vocab_size=model.vocab_size, seq_len=64,
                                 num_clients=5, heterogeneity=0.3,
                                 alpha=0.02))
params = init_params(model, jax.random.key(0))
opt = init_safl(safl, params)
loss = lambda p, b: loss_fn(model, p, b)
sampler = data.device_sampler(batch_per_client=8, local_steps=2)
sched = cosine(args.rounds, warmup=10)

# PackingPlan built once outside the trace; the fused multi-round driver
# (launch/driver.py) scans whole chunks on device with donated carries and
# checkpoints at chunk boundaries.  The cosine server LR rides in through
# kwargs_fn as a function of the scanned round index.
if args.fedopt and args.async_buffer > 0:
    ap.error("--async-buffer is SAFL-only; drop --fedopt to run the "
             "staleness buffer")
if args.fedopt and (args.faults > 0 or args.sentinel):
    ap.error("--faults/--sentinel act on the packed sketch uplink; the "
             "uncompressed FedOPT reference has no sketch payload")
if args.fedopt and args.codec:
    ap.error("--codec quantizes the packed sketch uplink; the uncompressed "
             "FedOPT reference has no sketch payload")
if args.codec and args.telemetry:
    ap.error("--telemetry probes read the bare server opt state; under the "
             "codec's error feedback the round state is the wrapped "
             "{'opt','ef'} dict -- run one or the other")

sentinel = SentinelConfig(norm_mult=10.0) if args.sentinel else None
codec = None
if args.codec:
    codec = CodecConfig(bits=8 if args.codec == "int8" else 1)
plan = make_packing_plan(safl.sketch, params)
async_cfg = None
if args.fedopt:
    round_fn = functools.partial(fedopt_round, safl, loss)
elif args.async_buffer > 0:
    async_cfg = AsyncConfig(max_delay=args.async_buffer, delay="uniform")
    round_fn = make_async_round(safl, loss, async_cfg, plan, codec=codec)
    opt = init_async_state(safl, async_cfg, params, plan,
                           data.cfg.num_clients, codec=codec)
else:
    round_fn = functools.partial(safl_round, safl, loss, plan=plan)
    if codec is not None:
        # static config, binds like plan=/sentinel= (DESIGN.md §13).  The
        # error-feedback memory becomes an extra optimizer-state leaf so the
        # scan carries it and --resume round-trips it.
        round_fn = functools.partial(round_fn, codec=codec)
        if codec.error_feedback:
            opt = {"opt": opt,
                   "ef": init_codec_state(codec, data.cfg.num_clients,
                                          plan.b_total)}
if sentinel is not None:
    # static config: binds like plan=, not a traced kwarg (DESIGN.md §10)
    round_fn = functools.partial(round_fn, sentinel=sentinel)

telemetry = stream = None
if args.telemetry:
    telemetry = Telemetry()
    if args.async_buffer == 0:
        # static config, binds like plan=/sentinel=.  (The async round
        # closure owns its multi-generation aggregation and takes no probe
        # config; its arrival_weight/counter metrics still stream.)
        round_fn = functools.partial(round_fn, telemetry=telemetry)
    obs_dir = args.telemetry_out or (args.ckpt + "_obs")
    stream = ShardWriter(obs_dir)
    write_manifest(obs_dir, run="train_lm", sketch=safl.sketch,
                   config={k: v for k, v in vars(args).items()})
    print("telemetry: streaming metric shards to", obs_dir)

faults = None
if args.faults > 0:
    r = args.faults / 3.0
    faults = FaultConfig(num_clients=data.cfg.num_clients, drop_rate=r,
                         nan_rate=r, byzantine_rate=r)
    print(f"fault injection: total rate {args.faults} "
          f"(drop/NaN/Byzantine {r:.3f} each)"
          + ("" if args.sentinel else " -- UNGUARDED, pass --sentinel"))

participation = None
if args.participation_frac < 1.0:
    participation = UniformParticipation(data.cfg.num_clients,
                                         frac=args.participation_frac)
    print(f"partial participation: {participation.cohort_size}"
          f"/{data.cfg.num_clients} clients per round")
if async_cfg is not None:
    print(f"async staleness buffer: max delay {async_cfg.max_delay} rounds")
if codec is not None:
    print(f"payload codec: {args.codec} "
          f"({codec.payload_bits(plan.b_total)} measured bits/client/round "
          f"vs {32 * plan.b_total} float32)")

n = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
print(f"{'FedOPT' if args.fedopt else 'SAFL'} on {n/1e6:.1f}M params, "
      f"sketch ratio {args.ratio}")

key = jax.random.key(0)

start_round = 0
if args.resume:
    # the `like` tree fixes structure/dtypes, so a checkpoint from different
    # flags (other model / optimizer / async state) fails loudly here
    like = {"params": params, "opt": opt,
            "cursor": {"t": jnp.asarray(0), "key": jax.random.key_data(key)}}
    state, _step = restore_checkpoint(args.ckpt, like)
    params, opt = state["params"], state["opt"]
    key = jax.random.wrap_key_data(state["cursor"]["key"])
    start_round = int(state["cursor"]["t"])
    print(f"resuming from {args.ckpt}.npz at round {start_round}")


def on_chunk(t_done, p, o, hist):
    print(f"round {t_done - 1:4d}  loss {hist['loss'][-1]:.4f}")
    if args.max_retries == 0 and t_done < args.rounds:
        # resumable cursor: (t, key) pins where the trajectory restarts --
        # data, cohort masks, delays and sketch operators are all pure
        # functions of the absolute round index under this key.  (The
        # supervisor owns checkpointing when it is on: it must record the
        # REKEYED cursor of a retried span, not this run key.)
        save_checkpoint(args.ckpt, {"params": p, "opt": o,
                                    "cursor": {"t": jnp.asarray(t_done),
                                               "key": jax.random.key_data(key)}},
                        step=t_done)


if args.max_retries > 0:
    def launch(p, o, *, key, start_round, on_chunk):
        return run_scan(
            round_fn, sampler, p, o, rounds=args.rounds, key=key,
            chunk_size=100, kwargs_fn=lambda t: {"lr_scale": sched(t)},
            on_chunk=on_chunk, participation=participation,
            buffer=async_cfg is not None, faults=faults,
            start_round=start_round, stream=stream)

    params, opt, hist, recovery = run_supervised(
        launch, params, opt, rounds=args.rounds, key=key,
        config=SupervisorConfig(max_retries=args.max_retries),
        on_chunk=on_chunk, ckpt_path=args.ckpt, start_round=start_round,
        stream=stream)
    print(format_recovery_log(recovery))
else:
    params, opt, hist = run_scan(
        round_fn, sampler, params, opt, rounds=args.rounds, key=key,
        chunk_size=100, kwargs_fn=lambda t: {"lr_scale": sched(t)},
        on_chunk=on_chunk, participation=participation,
        buffer=async_cfg is not None, faults=faults,
        start_round=start_round, stream=stream)
    save_checkpoint(args.ckpt, {"params": params, "opt": opt,
                                "cursor": {"t": jnp.asarray(args.rounds),
                                           "key": jax.random.key_data(key)}},
                    step=args.rounds)
if stream is not None:
    print(format_summary(stream.summary()))
print("checkpoint saved to", args.ckpt + ".npz")
