"""Reproduce the paper's sketch-size study (Fig. 1 right / Fig. 3 / Fig. 6):
training error is monotone in sketch size b, and even extreme compression
(b ~ 0.2% of d) still converges -- the log-d communication claim.

    PYTHONPATH=src python examples/sketch_size_sweep.py
"""
import functools

import jax
import jax.numpy as jnp

from repro.core.adaptive import AdaConfig
from repro.core.safl import SAFLConfig, init_safl, safl_round
from repro.core.sketch import SketchConfig, total_sketch_bits
from repro.data import BigramLMData, LMDataConfig
from repro.models import ModelConfig, init_params, loss_fn

model = ModelConfig(name="sweep", arch_type="dense", num_layers=2,
                    d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
                    vocab_size=512)
data = BigramLMData(LMDataConfig(vocab_size=512, seq_len=32, num_clients=5,
                                 alpha=0.02))
loss = lambda p, b: loss_fn(model, p, b)
ROUNDS = 80

print(f"{'ratio':>8} {'uplinkKiB':>10} {'final_loss':>11}  loss curve (every 20)")
results = {}
for ratio in (0.002, 0.01, 0.05, 0.25, 1.0):
    kind = "none" if ratio == 1.0 else "countsketch"
    safl = SAFLConfig(sketch=SketchConfig(kind=kind, ratio=ratio, min_b=8),
                      server=AdaConfig(name="amsgrad", lr=0.01),
                      client_lr=0.5, local_steps=2)
    params = init_params(model, jax.random.key(0))
    opt = init_safl(safl, params)
    step = jax.jit(functools.partial(safl_round, safl, loss))
    curve = []
    for t in range(ROUNDS):
        batch = data.round_batch(8, 2, seed=t)
        params, opt, m = step(params, opt, batch, jax.random.key(t))
        curve.append(float(m["loss"]))
    kib = total_sketch_bits(safl.sketch, params) / 8 / 1024
    results[ratio] = curve[-1]
    pts = " ".join(f"{curve[i]:.3f}" for i in range(0, ROUNDS, 20))
    print(f"{ratio:8.3f} {kib:10.1f} {curve[-1]:11.4f}  {pts}")

rs = sorted(results)
assert all(results[rs[i]] >= results[rs[i + 1]] - 0.05
           for i in range(len(rs) - 1)), \
    "training error should be (approximately) monotone in sketch size"
print("\nmonotonicity in b: OK (matches paper Fig. 1/3)")
