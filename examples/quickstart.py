"""Quickstart: SAFL on a tiny LM, inspecting every moving part.

The 60-round run executes as on-device scanned chunks (launch/driver.py):
the PackingPlan is built once, each scan step samples its own federated
batch on device, and losses come back one chunk at a time.

    PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax
import jax.numpy as jnp

from repro.core.adaptive import AdaConfig
from repro.core.packed import make_packing_plan
from repro.core.safl import SAFLConfig, init_safl, safl_round, \
    uplink_bits_per_round
from repro.core.sketch import SketchConfig
from repro.data import BigramLMData, LMDataConfig
from repro.launch.driver import run_scan
from repro.models import ModelConfig, init_params, loss_fn

model = ModelConfig(name="tiny", arch_type="dense", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128)
safl = SAFLConfig(
    sketch=SketchConfig(kind="countsketch", ratio=0.05, min_b=16),
    server=AdaConfig(name="amsgrad", lr=0.01),       # Algorithm 2
    client_lr=0.5, local_steps=2)                    # K = 2 local SGD steps

params = init_params(model, jax.random.key(0))
opt = init_safl(safl, params)
d = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
print(f"model: d = {d:,} parameters")
print(f"uplink per round: {uplink_bits_per_round(safl, params) / 8 / 1024:.1f}"
      f" KiB  (dense would be {d * 4 / 1024:.1f} KiB -> "
      f"{d * 32 / uplink_bits_per_round(safl, params):.0f}x compression)")

data = BigramLMData(LMDataConfig(vocab_size=128, seq_len=32, num_clients=5,
                                 alpha=0.03))
sampler = data.device_sampler(batch_per_client=8, local_steps=2)
loss = lambda p, b: loss_fn(model, p, b)

# static sketch layout once; the round operator re-derives per scanned key
plan = make_packing_plan(safl.sketch, params)
round_fn = functools.partial(safl_round, safl, loss, plan=plan)
bits = uplink_bits_per_round(safl, params)

params, opt, hist = run_scan(
    round_fn, sampler, params, opt, rounds=60, key=jax.random.key(0),
    chunk_size=10, bits_per_round=bits,
    on_chunk=lambda t, p, s, h: print(
        f"round {t - 1:3d}  mean client loss = {h['loss'][-1]:.4f}"))
print(f"done: loss {hist['loss'][0]:.4f} -> {hist['loss'][-1]:.4f} with a "
      f"{d * 32 / bits:.0f}x-compressed uplink, "
      f"{int(hist['uplink_bits'].sum() / 8 / 1024)} KiB total uplink, "
      f"6 device dispatches for 60 rounds.")
