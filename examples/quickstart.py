"""Quickstart: one SAFL round on a tiny LM, inspecting every moving part.

    PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax
import jax.numpy as jnp

from repro.core.adaptive import AdaConfig
from repro.core.safl import SAFLConfig, init_safl, safl_round, \
    uplink_bits_per_round
from repro.core.sketch import SketchConfig
from repro.data import BigramLMData, LMDataConfig
from repro.models import ModelConfig, init_params, loss_fn

model = ModelConfig(name="tiny", arch_type="dense", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128)
safl = SAFLConfig(
    sketch=SketchConfig(kind="countsketch", ratio=0.05, min_b=16),
    server=AdaConfig(name="amsgrad", lr=0.01),       # Algorithm 2
    client_lr=0.5, local_steps=2)   # K = 2 local SGD steps                   # K = 2 local SGD steps

params = init_params(model, jax.random.key(0))
opt = init_safl(safl, params)
d = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
print(f"model: d = {d:,} parameters")
print(f"uplink per round: {uplink_bits_per_round(safl, params) / 8 / 1024:.1f}"
      f" KiB  (dense would be {d * 4 / 1024:.1f} KiB -> "
      f"{d * 32 / uplink_bits_per_round(safl, params):.0f}x compression)")

data = BigramLMData(LMDataConfig(vocab_size=128, seq_len=32, num_clients=5,
                                 alpha=0.03))
loss = lambda p, b: loss_fn(model, p, b)
step = jax.jit(functools.partial(safl_round, safl, loss))

for t in range(60):
    batch = data.round_batch(batch_per_client=8, local_steps=2, seed=t)
    params, opt, metrics = step(params, opt, batch, jax.random.key(t))
    if t % 10 == 0 or t == 59:
        print(f"round {t:3d}  mean client loss = {float(metrics['loss']):.4f}")
print("done: loss decreased with a 20x-compressed uplink.")
