"""Heavy-tailed client noise: clipped SAFL vs plain SAFL (paper §2 noise
discussion / Chezhegov et al. 2024 — adaptive methods need clipping under
heavy tails).

    PYTHONPATH=src python examples/heavy_tail.py
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptive import AdaConfig
from repro.core.clipped import ClippedSAFLConfig, clipped_safl_round
from repro.core.safl import SAFLConfig, init_safl, safl_round
from repro.core.sketch import SketchConfig

key = jax.random.key(0)
W_true = jax.random.normal(jax.random.fold_in(key, 1), (32, 4))


def make_batch(seed, n=64, tail=1.2):
    """Regression with Pareto(alpha=1.2) label noise: INFINITE variance —
    the genuinely heavy-tailed regime where unclipped adaptive methods
    suffer."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 32)).astype(np.float32)
    noise = (rng.pareto(tail, size=(n, 4)) * rng.choice([-1, 1], (n, 4)))
    y = x @ np.asarray(W_true) + 0.5 * noise.astype(np.float32)
    b = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    return jax.tree.map(lambda v: v.reshape(4, 2, 8, *v.shape[1:]), b)


def loss_fn(p, b):
    return jnp.mean((b["x"] @ p["W"] - b["y"]) ** 2)


base = SAFLConfig(sketch=SketchConfig(kind="countsketch", ratio=0.5, min_b=8),
                  server=AdaConfig(name="amsgrad", lr=0.05),
                  client_lr=0.05, local_steps=2)

for name, tau in [("plain SAFL", None), ("clipped SAFL tau=0.5", 0.5)]:
    params = {"W": jnp.zeros((32, 4))}
    opt = init_safl(base, params)
    if tau is None:
        step = jax.jit(functools.partial(safl_round, base, loss_fn))
    else:
        ccfg = ClippedSAFLConfig(base=base, clip_tau=tau)
        step = jax.jit(functools.partial(clipped_safl_round, ccfg, loss_fn))
    errs = []
    for t in range(150):
        params, opt, m = step(params, opt, make_batch(t), jax.random.key(t))
        errs.append(float(jnp.mean((params["W"] - W_true) ** 2)))
    print(f"{name:24s} param-MSE: start {errs[0]:.3f}  "
          f"mid {errs[75]:.3f}  final {errs[-1]:.4f}")
print("clipping should give a lower, more stable final parameter error")
