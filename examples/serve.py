"""Serve a small model with batched greedy decoding through the KV-cache
decode path (the same decode_step the production dry-run lowers).

A minimal, config-free version of ``repro.launch.serve``: builds a small
sliding-window-attention transformer inline, initializes its ring-buffered
KV cache, and greedy-decodes a batch of sequences one token at a time
through a jitted ``decode_step``, printing tokens/sec and the head of the
first decoded sequence.  Use this to sanity-check the decode path (cache
layout, SWA ring indexing, argmax sampling) on any machine in seconds;
``python -m repro.launch.serve`` is the flagged driver for the real named
architectures.

    PYTHONPATH=src python examples/serve.py
"""
import time

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, decode_step, init_cache, init_params

model = ModelConfig(name="serve", arch_type="dense", num_layers=4,
                    d_model=256, num_heads=8, num_kv_heads=4, d_ff=512,
                    vocab_size=1024, sliding_window=64)
params = init_params(model, jax.random.key(0))

BATCH, STEPS, MAXSEQ = 8, 48, 64
cache = init_cache(model, BATCH, MAXSEQ)
step = jax.jit(lambda p, c, t, i: decode_step(model, p, c, t, i))

tokens = jax.random.randint(jax.random.key(1), (BATCH, 1), 0, 1024)
out = [tokens]
t0 = time.perf_counter()
for i in range(STEPS):
    logits, cache = step(params, cache, tokens, jnp.asarray(i, jnp.int32))
    tokens = jnp.argmax(logits, axis=-1)[:, None]
    out.append(tokens)
dt = time.perf_counter() - t0
seqs = jnp.concatenate(out, axis=1)
print(f"decoded {BATCH} x {STEPS} tokens in {dt:.2f}s "
      f"({BATCH * STEPS / dt:.0f} tok/s on CPU, ring-buffered SWA cache)")
print("first sequence:", seqs[0, :16].tolist(), "...")
assert bool(jnp.isfinite(logits).all())
