"""Fail CI when any test FILE was skipped entirely.

    python tools/check_skipped_files.py JUNIT.xml [JUNIT2.xml ...]

Reads one or more pytest ``--junitxml`` reports and unions them: a test
module counts as alive if ANY report ran at least one of its tests
un-skipped.  A module whose every collected test is skipped in every
report is a silently dead suite -- exactly the failure mode
``pytest.importorskip`` (hypothesis), device-count gates, and jax-version
gates can hide when an install step quietly stops providing a dependency.
CI passes both the tier-1 session's report and the dedicated 8-device
mesh session's, so ``tests/test_mesh_scan.py`` (device-gated in the
single-device session by design) is judged by the session that can
actually run it.
"""

from __future__ import annotations

import sys
import xml.etree.ElementTree as ET
from collections import defaultdict


def module_of(tc: ET.Element) -> str:
    """junit testcase -> test module.  Normal cases carry the dotted module
    in ``classname`` (drop trailing CamelCase class parts; this repo's
    tests are module-level functions, so usually a no-op).  A module
    skipped AT COLLECTION (e.g. a failed ``importorskip``) has an empty
    classname and the module in ``name`` -- the very case this checker
    exists to catch."""
    classname = tc.get("classname", "") or tc.get("name", "")
    parts = []
    for c in classname.split("."):
        if c[:1].isupper():
            break
        parts.append(c)
    return ".".join(parts) or classname or "<unknown>"


def tally(paths: list[str]) -> tuple[dict, dict]:
    total: dict[str, int] = defaultdict(int)
    ran: dict[str, int] = defaultdict(int)
    for path in paths:
        for tc in ET.parse(path).getroot().iter("testcase"):
            mod = module_of(tc)
            total[mod] += 1
            if tc.find("skipped") is None:
                ran[mod] += 1
    return total, ran


def main(argv: list[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    total, ran = tally(argv)
    if not total:
        print("no testcases found in", argv)
        return 1
    dead = sorted(m for m in total if ran[m] == 0)
    for mod in sorted(total):
        print(f"{mod}: {ran[mod]}/{total[mod]} ran"
              + ("   << ENTIRELY SKIPPED" if ran[mod] == 0 else ""))
    if dead:
        print(f"\n{len(dead)} test module(s) entirely skipped: "
              f"{', '.join(dead)} -- a gate or optional dependency is "
              "silently disabling coverage")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
