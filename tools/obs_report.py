"""Render a telemetry run directory as a text report (DESIGN.md §11).

    python tools/obs_report.py RUN_DIR [--no-profile]

Sections: manifest summary, per-key metric summary (last-wins over
duplicate rounds from supervised retries), wall-time spans with the
compile chunk split from steady state (p50/p95 per round), recovery
events, and -- unless ``--no-profile`` -- the roofline/HLO-cost section,
which compiles a bench-scale SAFL scan chunk on the local backend and runs
the ``repro.launch.roofline`` + ``hlo_costs`` analyses on it (the
previously idle DESIGN §6 tooling).  See ``repro.obs.report`` for the
implementation.
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv: list[str]) -> int:
    profile = "--no-profile" not in argv
    paths = [a for a in argv if not a.startswith("--")]
    if len(paths) != 1:
        print(__doc__)
        return 2
    if not os.path.isdir(paths[0]):
        print(f"# not a run directory: {paths[0]}")
        return 2
    from repro.obs.report import render
    sys.stdout.write(render(paths[0], profile=profile))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
