"""Schema validator for a telemetry run directory (DESIGN.md §11).

    python tools/check_telemetry.py RUN_DIR [--rounds N]

Checks, exiting non-zero on the first class of failure:

* ``manifest.json`` exists, parses, and carries every
  ``repro.obs.manifest.REQUIRED_KEYS`` key;
* every ``metrics-*.jsonl`` line parses, has ``kind == "metrics"`` and an
  integer ``t``, and every other key is drawn from the single source of
  truth ``repro.launch.driver.HISTORY_KEYS`` with a finite-or-nan float
  value;
* ``t`` is strictly monotonic WITHIN each shard (across shards it may
  restart: the rollback supervisor re-emits retried spans in new shards,
  and readers resolve duplicate ``t`` last-wins);
* every ``events.jsonl`` line parses with ``kind`` in {span, recovery} and
  that kind's required fields (span: t0/t1/seconds/compile; recovery:
  retry/t_fault/t_resume/depth/reason);
* with ``--rounds N``: the number of DISTINCT metric rounds equals N.

CI runs this against the mini-dryrun's ``--telemetry`` artifact so a
schema regression (a renamed key, a non-JSON line, a shard with
non-monotonic rounds) fails the build rather than silently producing
unreadable artifacts.
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SPAN_FIELDS = ("t0", "t1", "seconds", "compile")
RECOVERY_FIELDS = ("retry", "t_fault", "t_resume", "depth", "reason")


def check(run_dir: str, rounds: int | None = None) -> list[str]:
    """Returns a list of schema violations (empty = valid)."""
    from repro.launch.driver import HISTORY_KEYS
    from repro.obs.manifest import REQUIRED_KEYS

    errs: list[str] = []

    mpath = os.path.join(run_dir, "manifest.json")
    if not os.path.exists(mpath):
        errs.append("manifest.json missing")
    else:
        try:
            with open(mpath) as f:
                man = json.load(f)
            for k in REQUIRED_KEYS:
                if k not in man:
                    errs.append(f"manifest.json: required key {k!r} missing")
        except json.JSONDecodeError as e:
            errs.append(f"manifest.json: does not parse ({e})")

    allowed = {"kind", "t"} | set(HISTORY_KEYS)
    shards = sorted(glob.glob(os.path.join(run_dir, "metrics-*.jsonl")))
    if not shards:
        errs.append("no metrics-*.jsonl shards")
    seen_t: set[int] = set()
    for path in shards:
        name = os.path.basename(path)
        prev_t = None
        with open(path) as f:
            for i, line in enumerate(f):
                if not line.strip():
                    continue
                try:
                    row = json.loads(line)
                except json.JSONDecodeError as e:
                    errs.append(f"{name}:{i + 1}: does not parse ({e})")
                    continue
                if row.get("kind") != "metrics":
                    errs.append(f"{name}:{i + 1}: kind != 'metrics'")
                t = row.get("t")
                if not isinstance(t, int):
                    errs.append(f"{name}:{i + 1}: non-integer t {t!r}")
                    continue
                if prev_t is not None and t != prev_t + 1:
                    # within one shard rounds are consecutive; only ACROSS
                    # shards may t restart (supervisor rollback re-emission)
                    errs.append(f"{name}:{i + 1}: t {t} after {prev_t} "
                                "(not consecutive within shard)")
                prev_t = t
                seen_t.add(t)
                for k, v in row.items():
                    if k == "kind" or k == "t":
                        continue
                    if k not in allowed:
                        errs.append(f"{name}:{i + 1}: unknown key {k!r} "
                                    "(not in driver.HISTORY_KEYS)")
                    elif not isinstance(v, (int, float)):
                        errs.append(f"{name}:{i + 1}: {k} is {type(v).__name__},"
                                    " expected number")

    epath = os.path.join(run_dir, "events.jsonl")
    if os.path.exists(epath):
        with open(epath) as f:
            for i, line in enumerate(f):
                if not line.strip():
                    continue
                try:
                    ev = json.loads(line)
                except json.JSONDecodeError as e:
                    errs.append(f"events.jsonl:{i + 1}: does not parse ({e})")
                    continue
                kind = ev.get("kind")
                if kind == "span":
                    need = SPAN_FIELDS
                elif kind == "recovery":
                    need = RECOVERY_FIELDS
                else:
                    errs.append(f"events.jsonl:{i + 1}: unknown kind {kind!r}")
                    continue
                for k in need:
                    if k not in ev:
                        errs.append(f"events.jsonl:{i + 1}: {kind} event "
                                    f"missing {k!r}")

    if rounds is not None and len(seen_t) != rounds:
        errs.append(f"distinct metric rounds {len(seen_t)} != expected "
                    f"{rounds}")
    return errs


def main(argv: list[str]) -> int:
    rounds = None
    if "--rounds" in argv:
        i = argv.index("--rounds")
        rounds = int(argv[i + 1])
        argv = argv[:i] + argv[i + 2:]
    if len(argv) != 1:
        print(__doc__)
        return 2
    errs = check(argv[0], rounds)
    if errs:
        print(f"# telemetry schema check FAILED ({len(errs)} violation(s))")
        for e in errs[:50]:
            print("#   " + e)
        return 1
    print(f"# telemetry schema ok: {argv[0]}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
