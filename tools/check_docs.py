"""Docs consistency gate (README / ROADMAP / docstrings vs reality).

    PYTHONPATH=src python tools/check_docs.py

Two classes of drift, each a CI failure:

* **Dangling DESIGN section references.**  Every ``DESIGN §N`` /
  ``DESIGN.md §N`` reference in README.md, ROADMAP.md, and the Python
  sources (src/, examples/, tools/, benchmarks/, tests/) must point at a
  section that actually exists as a ``## §N ...`` header in DESIGN.md.
  References to a named appendix (``appendix "..."`` near a DESIGN
  mention) must match a ``## Appendix: ...`` header.  NOTE the pattern
  requires the ``DESIGN`` prefix on purpose: bare ``§N`` also names
  sections of the source PAPER (e.g. "paper §2" in core/clipped.py) and
  must not be checked against DESIGN.md.

* **Phantom CLI flags.**  Every backticked ``--flag`` token in README.md
  must be a real ``examples/train_lm.py`` flag (parsed from its
  ``add_argument`` calls -- the module runs argparse at import, so the
  SOURCE is the single safely-readable truth) or one of the known
  benchmark/pytest flags in ``FLAG_ALLOWLIST``.

Exits non-zero listing every failure, so a PR that renumbers DESIGN.md
or renames a flag cannot leave the front-door docs pointing at nothing.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# flags documented in README that belong to other entry points:
# benchmarks/run.py's mode flags (it parses sys.argv directly)
FLAG_ALLOWLIST = {"--quick", "--json", "--guard", "--mesh"}

# requires the DESIGN prefix -- bare "§N" may cite the source paper
SECTION_REF = re.compile(r"DESIGN(?:\.md)?\s+§§?(\d+)")
APPENDIX_REF = re.compile(r'appendix\s+"([^"]+)"', re.IGNORECASE)


def design_sections(design: str) -> tuple[set[int], set[str]]:
    nums = {int(m.group(1))
            for m in re.finditer(r"^## §(\d+)\s", design, re.MULTILINE)}
    appendices = {m.group(1).strip()
                  for m in re.finditer(r"^## Appendix:\s*(.+)$", design,
                                       re.MULTILINE)}
    return nums, appendices


def train_lm_flags() -> set[str]:
    src = (ROOT / "examples" / "train_lm.py").read_text()
    return set(re.findall(r'add_argument\(\s*"(--[A-Za-z0-9-]+)"', src))


def doc_files() -> list[pathlib.Path]:
    files = [ROOT / "README.md", ROOT / "ROADMAP.md"]
    for sub in ("src", "examples", "tools", "benchmarks", "tests"):
        files.extend(sorted((ROOT / sub).rglob("*.py")))
    me = pathlib.Path(__file__).resolve()
    return [f for f in files if f.is_file() and f.resolve() != me]


def main() -> int:
    design = (ROOT / "DESIGN.md").read_text()
    sections, appendices = design_sections(design)
    if not sections:
        print("check_docs: no '## §N' headers found in DESIGN.md")
        return 1

    fails: list[str] = []

    for f in doc_files():
        text = f.read_text()
        rel = f.relative_to(ROOT)
        for m in SECTION_REF.finditer(text):
            n = int(m.group(1))
            if n not in sections:
                line = text.count("\n", 0, m.start()) + 1
                fails.append(f"{rel}:{line}: DESIGN §{n} does not exist "
                             f"(have §{min(sections)}-§{max(sections)})")
        for m in APPENDIX_REF.finditer(text):
            name = m.group(1).strip()
            # only vet names that are plausibly OUR appendix: quoted after
            # the word 'appendix'; skip if DESIGN.md never had appendices
            if appendices and name not in appendices:
                line = text.count("\n", 0, m.start()) + 1
                fails.append(f'{rel}:{line}: appendix "{name}" not in '
                             f"DESIGN.md (have: {sorted(appendices)})")

    flags = train_lm_flags() | FLAG_ALLOWLIST
    readme = (ROOT / "README.md").read_text()
    for m in re.finditer(r"`([^`\n]+)`", readme):
        for tok in re.findall(r"--[A-Za-z0-9][A-Za-z0-9_-]*", m.group(1)):
            if tok not in flags:
                line = readme.count("\n", 0, m.start()) + 1
                fails.append(f"README.md:{line}: documented flag {tok} is "
                             "not a train_lm.py flag (or allowlisted "
                             "benchmark flag)")

    if fails:
        print(f"check_docs: {len(fails)} failure(s)")
        for msg in fails:
            print("  " + msg)
        return 1
    print(f"check_docs: ok ({len(sections)} DESIGN sections, "
          f"{len(appendices)} appendix(es), {len(flags)} known flags)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
