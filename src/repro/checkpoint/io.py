"""Checkpointing: exact pytree round-trip via npz + structure manifest."""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def _paths(tree: Pytree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        spath = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                         for k in path)
        out.append((spath, leaf))
    return out


def save_checkpoint(path: str, tree: Pytree, step: int = 0) -> None:
    """Save a pytree (params/opt state/server moments) to ``path``.npz.

    Writes are ATOMIC (tmp file + ``os.replace``), npz before manifest: a
    crash mid-save leaves either the previous checkpoint pair intact or a
    new npz with the old manifest -- never a torn npz a restart would then
    try to restore.  This is what lets the rollback supervisor
    (``launch/supervisor.py``) trust the last on-disk cursor."""
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    arrays = {}
    manifest = {"step": step, "leaves": []}
    for i, (spath, leaf) in enumerate(_paths(tree)):
        key = f"a{i}"
        arr = np.asarray(leaf)
        dtype = str(arr.dtype)
        if arr.dtype not in (np.float32, np.float64, np.int32, np.int64,
                             np.uint32, np.int16, np.uint8, np.int8,
                             np.bool_, np.float16, np.uint64, np.uint16):
            arr = arr.astype(np.float32)   # bf16 & friends: store widened
        arrays[key] = arr
        manifest["leaves"].append(
            {"path": spath, "key": key, "dtype": dtype})
    tmp = path + ".tmp.npz"
    np.savez(tmp, **arrays)
    os.replace(tmp, path + ".npz")
    tmp = path + ".tmp.json"
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, path + ".json")


def restore_checkpoint(path: str, like: Pytree) -> tuple[Pytree, int]:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path + ".npz")
    saved = {l["path"]: data[l["key"]] for l in manifest["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pathk, leaf in flat:
        spath = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                         for k in pathk)
        if spath not in saved:
            raise KeyError(f"checkpoint missing leaf {spath}")
        arr = saved[spath]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {spath}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    return tree, manifest["step"]
