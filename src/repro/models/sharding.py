"""Mesh context + sharding annotation helpers.

The model code calls ``hint(x, ...)`` at layer boundaries and around
attention/MoE internals.  When no production mesh is active (unit tests,
CPU examples) every hint is a no-op, so the same model code runs everywhere.

Axis convention (DESIGN §3):
  * ``pod`` , ``data`` -- batch / client-group axes (FSDP weight sharding
    also uses ``data``)
  * ``model``          -- tensor/expert parallel axis
"""

from __future__ import annotations

import contextlib
import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_ACTIVE_MESH: Optional[Mesh] = None
_MANUAL_AXES: frozenset = frozenset()   # axes currently manual (shard_map)
_MODEL_SUBST = None                      # flat-TP: what "model" expands to

BATCH = ("pod", "data")   # canonical batch axes (pod may be absent)
MODEL = "model"
FSDP = "data"             # weights' secondary shard axis


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Activate a mesh for both GSPMD resolution and our hint() helper."""
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        if mesh is None:
            yield
        else:
            with mesh:
                yield
    finally:
        _ACTIVE_MESH = prev


@contextlib.contextmanager
def model_axis_substitution(axes):
    """Flat-TP serving (DESIGN §7 / EXPERIMENTS H3): every 'model' hint in
    the layer code expands to the given axis tuple, e.g. ("data","model")."""
    global _MODEL_SUBST
    prev = _MODEL_SUBST
    _MODEL_SUBST = tuple(axes)
    try:
        yield
    finally:
        _MODEL_SUBST = prev


@contextlib.contextmanager
def manual_axes(axes):
    """Mark mesh axes as manual while tracing a shard_map body: hint() must
    not emit sharding constraints over manual axes."""
    global _MANUAL_AXES
    prev = _MANUAL_AXES
    _MANUAL_AXES = frozenset(axes)
    try:
        yield
    finally:
        _MANUAL_AXES = prev


def _clean_spec(spec) -> Optional[P]:
    """Drop axis names not present in the active mesh; None if no mesh."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return None
    names = set(mesh.axis_names) - _MANUAL_AXES
    out = []
    for e in spec:
        if e is None:
            out.append(None)
            continue
        t = e if isinstance(e, tuple) else (e,)
        if _MODEL_SUBST is not None:
            if MODEL in t:
                t2 = []
                for a in t:
                    if a == MODEL:
                        t2.extend(_MODEL_SUBST)
                    else:
                        t2.append(a)
                t = tuple(dict.fromkeys(t2))
            else:
                # batch-axis entries: axes consumed by the flat TP product
                # cannot also shard the batch -> drop them (replicated)
                t = tuple(a for a in t if a not in _MODEL_SUBST)
        t = tuple(a for a in t if a in names)
        out.append(t if len(t) > 1 else (t[0] if t else None))
    return P(*out)


def hint_replicated(x: jax.Array):
    """Explicitly replicate (hint() treats all-None specs as no-ops)."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*((None,) * x.ndim)))


def hint(x: jax.Array, *spec):
    """with_sharding_constraint that degrades to a no-op off-mesh, or when
    every referenced axis is manual/absent (never force replication)."""
    p = _clean_spec(spec)
    if p is None or all(e is None for e in p):
        return x
    return jax.lax.with_sharding_constraint(x, p)


def batch_spec(*rest) -> tuple:
    """P((pod, data), *rest) -- batch-sharded leading dim."""
    return (BATCH,) + rest


# ---------------------------------------------------------------------------
# Parameter partition rules (name-based; see DESIGN §3).
# Keys are regexes over the flattened path; first match wins.  Every weight
# is 2-D sharded: one dim on "model" (TP/EP) and one on "data" (FSDP/ZeRO),
# so even 671B-scale configs shard across the full chip count.
# ---------------------------------------------------------------------------

_RULES: list[tuple[str, tuple]] = [
    # embeddings / heads
    (r"embed$",            (MODEL, FSDP)),           # (V, D)
    (r"lm_head$",          (FSDP, MODEL)),           # (D, V)
    (r"mtp_head$",         (FSDP, MODEL)),
    (r"pos_embed$",        (None, MODEL)),
    # MoE experts: (E, in, out) -- experts over model (EP), in-dim over data
    (r"moe/w[ig]$",        (MODEL, FSDP, None)),
    (r"moe/wo$",           (MODEL, None, FSDP)),
    (r"moe/router$",       (FSDP, None)),
    (r"shared/w[ig]$",     (FSDP, MODEL)),
    (r"shared/wo$",        (MODEL, FSDP)),
    # attention (col-parallel in, row-parallel out)
    (r"attn/w[qkv]$",      (FSDP, MODEL)),
    (r"attn/wo$",          (MODEL, FSDP)),
    (r"attn/w_dq$",        (FSDP, None)),            # MLA down-projections
    (r"attn/w_uq$",        (None, MODEL)),
    (r"attn/w_dkv$",       (FSDP, None)),
    (r"attn/w_kr$",        (FSDP, None)),
    (r"attn/w_uk$",        (None, MODEL)),
    (r"attn/w_uv$",        (None, MODEL)),
    # dense MLP
    (r"mlp/w[ig]$",        (FSDP, MODEL)),
    (r"mlp/wo$",           (MODEL, FSDP)),
    # mamba
    (r"mamba/w[xz]$",      (FSDP, MODEL)),           # (D, d_inner)
    (r"mamba/out_proj$",   (MODEL, FSDP)),           # (d_inner, D)
    (r"mamba/x_proj$",     (MODEL, None)),           # (d_inner, dtr+2ds)
    (r"mamba/dt_proj$",    (None, MODEL)),           # (dtr, d_inner)
    (r"mamba/conv_w$",     (None, MODEL)),           # (k, d_inner)
    (r"mamba/(conv_b|dt_bias|d_skip)$", (MODEL,)),
    (r"mamba/a_log$",      (MODEL, None)),           # (d_inner, d_state)
    # biases on col-parallel projections
    (r"attn/b[qkv]$",      (MODEL,)),
    # everything else (norms, small biases): replicated
]


def _pspec_for(path: str, ndim: int, stacked: bool) -> P:
    for pat, spec in _RULES:
        if re.search(pat, path):
            spec = tuple(spec)
            if stacked:
                spec = (None,) + spec  # leading layer-stack dim
            spec = spec + (None,) * (ndim - len(spec))
            return P(*spec[:ndim])
    return P(*((None,) * ndim))


def param_pspecs(params, fsdp: bool = False) -> "jax.tree_util.PyTreeDef":
    """PartitionSpec pytree for a model param tree (launch/dryrun input).

    fsdp=False: weights sharded over ``model`` only, replicated over
    data -- the cross-device FL mapping (every data group = one client owns
    a full replica).  fsdp=True: weights additionally ZeRO-sharded over
    ``data`` -- the cross-silo mapping (client = pod; mandatory for the
    132B-672B configs).  See DESIGN §3."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        spath = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        parts = spath.split("/")
        stacked = bool({"layers", "dense_layers", "enc_layers"} & set(parts))
        spec = _pspec_for(spath, leaf.ndim, stacked)
        if not fsdp:
            spec = P(*[None if e == FSDP else e for e in spec])
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def named_shardings(mesh: Mesh, pspecs) -> "jax.tree_util.PyTreeDef":
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
