from repro.models.config import ModelConfig
from repro.models.model import (cache_shapes, count_params_analytic,
                                decode_step, forward, init_cache, init_params,
                                loss_fn, param_shapes)
from repro.models.sharding import param_pspecs, use_mesh

__all__ = ["ModelConfig", "forward", "loss_fn", "init_params", "param_shapes",
           "decode_step", "init_cache", "cache_shapes", "param_pspecs",
           "use_mesh", "count_params_analytic"]
