"""Composable model definition: init / forward / loss / decode for every
assigned architecture family, built as a lax.scan over stacked layer blocks
(compile time independent of depth -- DESIGN §5)."""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.sharding import BATCH, MODEL, hint

Pytree = Any
LOSS_CHUNK = 1024  # sequence chunk for the vocab-softmax loss


# ---------------------------------------------------------------------------
# parameter shapes / init
# ---------------------------------------------------------------------------

def _norm_shape(cfg: ModelConfig, d: int) -> dict:
    if cfg.norm_kind == "ln":
        return {"scale": (d,), "bias": (d,)}
    return {"scale": (d,)}


def _attn_shapes(cfg: ModelConfig, cross: bool = False) -> dict:
    D, H, Hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    if cfg.mla and not cross:
        return {
            "ln": _norm_shape(cfg, D),
            "w_dq": (D, cfg.q_lora_rank),
            "w_uq": (cfg.q_lora_rank, H * (cfg.qk_nope_dim + cfg.qk_rope_dim)),
            "w_dkv": (D, cfg.kv_lora_rank),
            "w_kr": (D, cfg.qk_rope_dim),
            "w_uk": (cfg.kv_lora_rank, H * cfg.qk_nope_dim),
            "w_uv": (cfg.kv_lora_rank, H * cfg.v_head_dim),
            "wo": (H * cfg.v_head_dim, D),
        }
    s = {
        "ln": _norm_shape(cfg, D),
        "wq": (D, H * hd), "wk": (D, Hk * hd), "wv": (D, Hk * hd),
        "wo": (H * hd, D),
    }
    if cfg.attn_bias and not cross:
        s.update({"bq": (H * hd,), "bk": (Hk * hd,), "bv": (Hk * hd,)})
    return s


def _mlp_shapes(cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    if cfg.mlp_kind == "gelu":
        return {"ln": _norm_shape(cfg, D), "wi": (D, F), "bi": (F,),
                "wo": (F, D), "bo": (D,)}
    return {"ln": _norm_shape(cfg, D), "wi": (D, F), "wg": (D, F), "wo": (F, D)}


def _moe_shapes(cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.moe_ff, cfg.num_experts
    s = {"ln": _norm_shape(cfg, D), "router": (D, E),
         "wi": (E, D, F), "wg": (E, D, F), "wo": (E, F, D)}
    if cfg.num_shared_experts:
        Fs = F * cfg.num_shared_experts
        s["shared"] = {"wi": (D, Fs), "wg": (D, Fs), "wo": (Fs, D)}
    return s


def _mamba_shapes(cfg: ModelConfig) -> dict:
    D, di, ds, dtr, kw = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                          cfg.dt_rank, cfg.ssm_conv)
    return {"ln": _norm_shape(cfg, D),
            "wx": (D, di), "wz": (D, di),
            "conv_w": (kw, di), "conv_b": (di,),
            "x_proj": (di, dtr + 2 * ds), "dt_proj": (dtr, di),
            "dt_bias": (di,), "a_log": (di, ds), "d_skip": (di,),
            "out_proj": (di, D)}


def _block_shapes(cfg: ModelConfig, pattern, cross: bool = False) -> dict:
    blk = {}
    for i, (mixer, mlp_kind) in enumerate(pattern):
        sub = {}
        if mixer == "attn":
            sub["attn"] = _attn_shapes(cfg)
            if cross:
                sub["xattn"] = _attn_shapes(cfg, cross=True)
        else:
            sub["mamba"] = _mamba_shapes(cfg)
        if mlp_kind == "dense":
            sub["mlp"] = _mlp_shapes(cfg)
        elif mlp_kind == "moe":
            sub["moe"] = _moe_shapes(cfg)
        blk[f"l{i}"] = sub
    return blk


def param_shapes(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.padded_vocab
    n_blocks, pattern = cfg.scan_blocks()
    shapes: dict = {"embed": (V, D),
                    "final_norm": _norm_shape(cfg, D),
                    "layers": _block_shapes(cfg, pattern,
                                            cross=cfg.cross_attention)}
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (D, V)
    if cfg.first_dense_layers:
        shapes["dense_layers"] = _block_shapes(
            cfg, [("attn", "dense")] * 1)  # stacked over first_dense_layers
    if cfg.encoder_layers:
        shapes["enc_layers"] = _block_shapes(cfg, [("attn", "dense")])
        shapes["enc_norm"] = _norm_shape(cfg, D)
    if cfg.mtp:
        shapes["mtp_head"] = (D, V)

    def stackify(tree, n):
        return jax.tree.map(lambda s: (n,) + tuple(s), tree,
                            is_leaf=lambda x: isinstance(x, tuple))

    shapes["layers"] = stackify(shapes["layers"], n_blocks)
    if cfg.first_dense_layers:
        shapes["dense_layers"] = stackify(shapes["dense_layers"],
                                          cfg.first_dense_layers)
    if cfg.encoder_layers:
        shapes["enc_layers"] = stackify(shapes["enc_layers"], cfg.encoder_layers)
    return shapes


def _init_leaf(key, path: str, shape, cfg: ModelConfig) -> jax.Array:
    """Initialize a single parameter tensor (fan-in scaled normal)."""
    dt = cfg.dtype
    if path.endswith(("scale", "d_skip")):
        return jnp.ones(shape, dt)
    if path.endswith(("bias", "conv_b", "bq", "bk", "bv", "bi", "bo")):
        return jnp.zeros(shape, dt)
    if path.endswith("dt_bias"):
        return jnp.full(shape, -4.6, dt)  # softplus ~= 0.01
    if path.endswith("a_log"):
        ds = shape[-1]
        a = jnp.tile(jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32)),
                     shape[:-1] + (1,))
        return a.astype(dt)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 0.02 if path.endswith(("embed", "lm_head", "mtp_head")) else \
        1.0 / math.sqrt(max(fan_in, 1))
    if path.endswith(("wo", "out_proj")):
        std /= math.sqrt(2.0 * max(cfg.num_layers, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dt)


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    leaves = []
    for i, (path, shape) in enumerate(flat):
        spath = "/".join(str(getattr(k, "key", k)) for k in path)
        leaves.append(_init_leaf(jax.random.fold_in(key, i), spath, shape, cfg))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = param_shapes(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    total = 0
    for path, shape in flat:
        spath = "/".join(str(getattr(k, "key", k)) for k in path)
        n = int(np.prod(shape))
        if active_only and "/moe/" in spath and spath.split("/")[-1] in \
                ("wi", "wg", "wo"):
            n = int(n * cfg.moe_top_k / max(cfg.num_experts, 1))
        total += n
    return total


# ---------------------------------------------------------------------------
# layer-block application (shared by train and decode paths)
# ---------------------------------------------------------------------------

def _apply_block(cfg: ModelConfig, pattern, blk: dict, x, positions, *,
                 enc_out=None, bidirectional=False):
    """All sub-layers of one scan block.  Returns (x, aux_sum)."""
    aux = jnp.zeros((), jnp.float32)
    for i, (mixer, mlp_kind) in enumerate(pattern):
        sub = blk[f"l{i}"]
        window = cfg.sliding_window if mixer == "attn" else 0
        if mixer == "attn":
            h = L.apply_norm(cfg, sub["attn"]["ln"], x)
            if cfg.mla:
                h = L.mla_attention(cfg, sub["attn"], h, positions)
            else:
                h = L.attention(cfg, sub["attn"], h, positions,
                                causal=not bidirectional, window=window)
            x = x + h
            if enc_out is not None and "xattn" in sub:
                h = L.apply_norm(cfg, sub["xattn"]["ln"], x)
                h = L.attention(cfg, sub["xattn"], h, positions,
                                enc_out=enc_out)
                x = x + h
        else:
            h = L.apply_norm(cfg, sub["mamba"]["ln"], x)
            x = x + L.mamba(cfg, sub["mamba"], h)
        if mlp_kind == "dense":
            h = L.apply_norm(cfg, sub["mlp"]["ln"], x)
            x = x + L.mlp(cfg, sub["mlp"], h)
        elif mlp_kind == "moe":
            h = L.apply_norm(cfg, sub["moe"]["ln"], x)
            h, a = L.moe(cfg, sub["moe"], h)
            x = x + h
            aux = aux + a
        x = hint(x, BATCH, MODEL, None)   # sequence-parallel residual stream
    return x, aux


def _scan_blocks(cfg: ModelConfig, pattern, stacked: dict, x, positions, *,
                 enc_out=None, bidirectional=False, remat=True):
    def body(carry, blk):
        xc, aux = carry
        fn = partial(_apply_block, cfg, pattern, enc_out=enc_out,
                     bidirectional=bidirectional)
        if remat:
            fn = jax.checkpoint(fn)
        xc, a = fn(blk, xc, positions)
        return (xc, aux + a), None

    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


# ---------------------------------------------------------------------------
# forward / loss (train + prefill)
# ---------------------------------------------------------------------------

def _positions_for(cfg: ModelConfig, batch: dict, B: int, S: int):
    if cfg.pos_kind == "mrope":
        P = cfg.num_frontend_tokens
        grid = max(1, int(math.isqrt(max(P, 1))))
        pidx = jnp.arange(P)
        t_pos = jnp.zeros((P,), jnp.int32)
        h_pos = (pidx // grid).astype(jnp.int32)
        w_pos = (pidx % grid).astype(jnp.int32)
        text = jnp.arange(S - P, dtype=jnp.int32) + grid
        tpos = jnp.concatenate([t_pos, text])
        hpos = jnp.concatenate([h_pos, text])
        wpos = jnp.concatenate([w_pos, text])
        pos = jnp.stack([tpos, hpos, wpos])                   # (3, S)
        return jnp.broadcast_to(pos[:, None, :], (3, B, S))
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))


def forward(cfg: ModelConfig, params: dict, batch: dict, *,
            remat: bool = True) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (hidden (B,S,D), aux_loss)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    n_blocks, pattern = cfg.scan_blocks()

    emb = jnp.take(params["embed"], tokens, axis=0)           # (B,St,D)
    if cfg.frontend == "vision":
        x = jnp.concatenate(
            [batch["patch_embeds"].astype(emb.dtype), emb], axis=1)
    else:
        x = emb
    S = x.shape[1]
    positions = _positions_for(cfg, batch, B, S)
    if cfg.pos_kind == "sinusoidal":
        x = x + L.sinusoidal_embed(jnp.arange(S), cfg.d_model)[None].astype(x.dtype)
    x = hint(x, BATCH, MODEL, None)

    enc_out = None
    if cfg.encoder_layers:
        e = batch["audio_embeds"].astype(x.dtype)
        Te = e.shape[1]
        e = e + L.sinusoidal_embed(jnp.arange(Te), cfg.d_model)[None].astype(e.dtype)
        e, _ = _scan_blocks(cfg, [("attn", "dense")], params["enc_layers"], e,
                            jnp.broadcast_to(jnp.arange(Te)[None], (B, Te)),
                            bidirectional=True, remat=remat)
        enc_out = L.apply_norm(cfg, params["enc_norm"], e)

    aux = jnp.zeros((), jnp.float32)
    if cfg.first_dense_layers:
        x, a = _scan_blocks(cfg, [("attn", "dense")], params["dense_layers"],
                            x, positions, remat=remat)
        aux += a
    x, a = _scan_blocks(cfg, pattern, params["layers"], x, positions,
                        enc_out=enc_out, remat=remat)
    aux += a
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, aux


def _logits(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ head


def _ce_loss_chunked(cfg, params, h, labels, mask, head_name="lm_head"):
    """Cross-entropy over the vocab, chunked along the sequence."""
    B, S, D = h.shape
    head = (params["embed"].T if cfg.tie_embeddings
            else params[head_name])
    sc = min(LOSS_CHUNK, S)
    n_chunks = -(-S // sc)
    s_pad = n_chunks * sc
    hp = jnp.pad(h, ((0, 0), (0, s_pad - S), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, s_pad - S)))
    mp = jnp.pad(mask, ((0, 0), (0, s_pad - S)))

    def chunk(args):
        hc, lc, mc = args
        logits = (hc @ head).astype(jnp.float32)
        logits = hint(logits, BATCH, None, MODEL)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via masked sum over the (model-sharded) vocab axis:
        # shard-local partial + tiny psum, instead of a cross-shard gather
        vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                             logits.ndim - 1)
        gold = jnp.sum(jnp.where(vocab_ids == lc[..., None], logits, 0.0),
                       axis=-1)
        return jnp.sum((lse - gold) * mc), jnp.sum(mc)

    if n_chunks == 1:
        tot, cnt = chunk((hp, lp, mp.astype(jnp.float32)))
    else:
        hs = hp.reshape(B, n_chunks, sc, D).swapaxes(0, 1)
        ls = lp.reshape(B, n_chunks, sc).swapaxes(0, 1)
        ms = mp.astype(jnp.float32).reshape(B, n_chunks, sc).swapaxes(0, 1)
        tots, cnts = lax.map(chunk, (hs, ls, ms))
        tot, cnt = jnp.sum(tots), jnp.sum(cnts)
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, *,
            remat: bool = True) -> jax.Array:
    """Next-token LM loss (masked to text positions for VLM; decoder tokens
    for enc-dec; +MTP auxiliary for DeepSeek)."""
    tokens = batch["tokens"]
    B, St = tokens.shape
    h, aux = forward(cfg, params, batch, remat=remat)
    P = cfg.num_frontend_tokens if cfg.frontend == "vision" else 0
    ht = h[:, P:]                                             # text hidden
    labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    mask = jnp.ones((B, St), bool).at[:, -1].set(False)
    loss = _ce_loss_chunked(cfg, params, ht, labels, mask)
    if cfg.mtp:
        labels2 = jnp.pad(tokens[:, 2:], ((0, 0), (0, 2)))
        mask2 = jnp.ones((B, St), bool).at[:, -2:].set(False)
        loss = loss + cfg.mtp_weight * _ce_loss_chunked(
            cfg, params, ht, labels2, mask2, head_name="mtp_head")
    return loss + aux


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def _cache_shapes_block(cfg: ModelConfig, pattern, B: int, max_seq: int,
                        cross: bool) -> dict:
    Hk, hd = cfg.num_kv_heads, cfg.hd
    out = {}
    for i, (mixer, _) in enumerate(pattern):
        sub = {}
        if mixer == "attn":
            if cfg.mla:
                sub["ckv"] = (B, max_seq, cfg.kv_lora_rank)
                sub["kpe"] = (B, max_seq, cfg.qk_rope_dim)
            else:
                sc = min(max_seq, cfg.sliding_window) if cfg.sliding_window \
                    else max_seq
                sub["k"] = (B, sc, Hk, hd)
                sub["v"] = (B, sc, Hk, hd)
            if cross:
                sub["xk"] = (B, cfg.encoder_seq, Hk, hd)
                sub["xv"] = (B, cfg.encoder_seq, Hk, hd)
        else:
            sub["h"] = (B, cfg.d_inner, cfg.ssm_state)
            sub["conv"] = (B, cfg.ssm_conv - 1, cfg.d_inner)
        out[f"l{i}"] = sub
    return out


def cache_shapes(cfg: ModelConfig, B: int, max_seq: int) -> dict:
    n_blocks, pattern = cfg.scan_blocks()

    def stackify(tree, n):
        return jax.tree.map(lambda s: (n,) + tuple(s), tree,
                            is_leaf=lambda x: isinstance(x, tuple))

    shapes = {"layers": stackify(
        _cache_shapes_block(cfg, pattern, B, max_seq, cfg.cross_attention),
        n_blocks)}
    if cfg.first_dense_layers:
        shapes["dense_layers"] = stackify(
            _cache_shapes_block(cfg, [("attn", "dense")], B, max_seq, False),
            cfg.first_dense_layers)
    return shapes


def _cache_dtype(cfg: ModelConfig, path: str):
    return jnp.float32 if path.endswith(("h",)) else cfg.dtype


def init_cache(cfg: ModelConfig, B: int, max_seq: int) -> dict:
    shapes = cache_shapes(cfg, B, max_seq)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    leaves = []
    for path, shape in flat:
        spath = "/".join(str(getattr(k, "key", k)) for k in path)
        leaves.append(jnp.zeros(shape, _cache_dtype(cfg, spath)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _decode_block(cfg: ModelConfig, pattern, blk, cache_blk, x, pos):
    new_cache = {}
    for i, (mixer, mlp_kind) in enumerate(pattern):
        sub, csub = blk[f"l{i}"], cache_blk[f"l{i}"]
        nsub = dict(csub)
        if mixer == "attn":
            h = L.apply_norm(cfg, sub["attn"]["ln"], x)
            if cfg.mla:
                h, upd = L.mla_attention_decode(cfg, sub["attn"], h, pos, csub)
            else:
                h, upd = L.attention_decode(cfg, sub["attn"], h, pos, csub,
                                            window=cfg.sliding_window)
            nsub.update(upd)
            x = x + h
            if "xk" in csub and "xattn" in sub:
                h = L.apply_norm(cfg, sub["xattn"]["ln"], x)
                x = x + L.cross_attention_decode(cfg, sub["xattn"], h, csub)
        else:
            h = L.apply_norm(cfg, sub["mamba"]["ln"], x)
            h, upd = L.mamba_decode(cfg, sub["mamba"], h, csub)
            nsub.update(upd)
            x = x + h
        if mlp_kind == "dense":
            h = L.apply_norm(cfg, sub["mlp"]["ln"], x)
            x = x + L.mlp(cfg, sub["mlp"], h)
        elif mlp_kind == "moe":
            h = L.apply_norm(cfg, sub["moe"]["ln"], x)
            h, _ = L.moe(cfg, sub["moe"], h)
            x = x + h
        new_cache[f"l{i}"] = nsub
    return x, new_cache


def encode_for_decode(cfg: ModelConfig, params: dict, cache: dict,
                      audio_embeds: jax.Array) -> dict:
    """Run the encoder once and fill the decoder blocks' cross-attention
    k/v caches (Whisper-style serving)."""
    B, Te, _ = audio_embeds.shape
    e = audio_embeds + L.sinusoidal_embed(
        jnp.arange(Te), cfg.d_model)[None].astype(audio_embeds.dtype)
    e, _ = _scan_blocks(cfg, [("attn", "dense")], params["enc_layers"], e,
                        jnp.broadcast_to(jnp.arange(Te)[None], (B, Te)),
                        bidirectional=True, remat=False)
    enc_out = L.apply_norm(cfg, params["enc_norm"], e)
    Hk, hd = cfg.num_kv_heads, cfg.hd

    def fill(blk_cache, blk_params):
        out = dict(blk_cache)
        for name, sub in blk_params.items():
            if "xattn" in sub:
                xk = (enc_out @ sub["xattn"]["wk"]).reshape(B, Te, Hk, hd)
                xv = (enc_out @ sub["xattn"]["wv"]).reshape(B, Te, Hk, hd)
                out[name] = {**blk_cache[name],
                             "xk": xk.astype(blk_cache[name]["xk"].dtype),
                             "xv": xv.astype(blk_cache[name]["xv"].dtype)}
        return out

    new_layers = jax.vmap(fill)(cache["layers"], params["layers"])
    return {**cache, "layers": new_layers}


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, pos: jax.Array
                ) -> tuple[jax.Array, dict]:
    """One-token decode.  tokens: (B, 1) int32; pos: scalar int32 (next
    position to fill).  Returns (logits (B, V), new_cache)."""
    n_blocks, pattern = cfg.scan_blocks()
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)             # (B,1,D)
    if cfg.pos_kind == "sinusoidal":
        x = x + L.sinusoidal_embed(pos[None], cfg.d_model)[None].astype(x.dtype)
    x = hint(x, BATCH, None, None)
    new_cache = {}
    if cfg.first_dense_layers:
        def dbody(carry, xs):
            blk, cb = xs
            xc, nc = _decode_block(cfg, [("attn", "dense")], blk, cb, carry, pos)
            return xc, nc
        x, nc = lax.scan(dbody, x, (params["dense_layers"],
                                    cache["dense_layers"]))
        new_cache["dense_layers"] = nc

    def body(carry, xs):
        blk, cb = xs
        xc, nc = _decode_block(cfg, pattern, blk, cb, carry, pos)
        return xc, nc

    x, nc = lax.scan(body, x, (params["layers"], cache["layers"]))
    new_cache["layers"] = nc
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = _logits(cfg, params, x)[:, 0, :cfg.vocab_size]
    logits = hint(logits, BATCH, MODEL)
    return logits, new_cache
