"""Model layers for all assigned architecture families.

Every layer has a full-sequence path (train / prefill) and a cached decode
path (one new token).  Memory discipline for the production mesh:

* attention is computed in query chunks (exact, softmax is over keys) so the
  (S x S) score matrix never materializes; sliding-window attention slices
  keys to the window => sub-quadratic compute;
* the Mamba selective scan runs chunk-sequentially (associative scan within
  a chunk) so the (S, d_inner, d_state) state tensor never materializes;
* MoE uses scatter-based capacity dispatch (no (T, E, C) one-hot einsum).

Sharding hints (no-ops off-mesh) implement sequence-parallel residual
streams + head/expert-parallel internals (DESIGN §3).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.sharding import BATCH, MODEL, hint, hint_replicated

Pytree = Any

Q_CHUNK = 512          # query chunk for blockwise attention
MAMBA_CHUNK = 256      # seq chunk for the selective scan
MOE_CHUNK = 4096       # token chunk for MoE dispatch


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm_kind == "ln":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# positions: RoPE, M-RoPE, sinusoidal
# ---------------------------------------------------------------------------

def rope_cos_sin(cfg: ModelConfig, positions: jax.Array, rot_dim: int):
    """cos/sin tables.  positions: (B, S) for rope, (3, B, S) for mrope.
    Returns (cos, sin) of shape (B, S, rot_dim // 2)."""
    half = rot_dim // 2
    if cfg.pos_kind == "mrope":
        secs = cfg.mrope_sections
        assert sum(secs) == half, (secs, half)
        inv = 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=jnp.float32) / half))
        parts, off = [], 0
        for i, s in enumerate(secs):
            ang = positions[i][..., None].astype(jnp.float32) * inv[off:off + s]
            parts.append(ang)
            off += s
        ang = jnp.concatenate(parts, axis=-1)
    else:
        inv = 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=jnp.float32) / half))
        ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, rot) rotated pairwise (half-split convention)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def sinusoidal_embed(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    inv = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention (GQA + optional SWA / cross) -- full-sequence path
# ---------------------------------------------------------------------------

def _attend_chunked(q, k, v, *, causal: bool, window: int, q_offset: int,
                    num_kv: int) -> jax.Array:
    """Blockwise exact attention.

    q: (B, S, H, hd); k, v: (B, T, Hk, hd).  Softmax is over keys, so
    chunking queries is exact.  For SWA, keys are sliced per chunk.
    Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    hd_v = v.shape[-1]
    T = k.shape[1]
    g = H // num_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    cq = min(Q_CHUNK, S)
    n_chunks = -(-S // cq)
    s_pad = n_chunks * cq
    if s_pad != S:
        q = jnp.pad(q, ((0, 0), (0, s_pad - S), (0, 0), (0, 0)))

    use_window = causal and window > 0 and T > window
    lk = min(T, window + cq) if use_window else T

    # GQA: expand kv to H heads with repeat (head dim replicated before the
    # repeat, sharded after) -- never reshape a sharded head axis, which the
    # SPMD partitioner cannot regroup (DESIGN §3).
    if g > 1:
        k = hint(k, BATCH, None, None, None)
        v = hint(v, BATCH, None, None, None)
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    k = hint(k, BATCH, None, MODEL, None)
    v = hint(v, BATCH, None, MODEL, None)

    def one_chunk(c):
        c0 = c * cq
        qc = lax.dynamic_slice_in_dim(q, c0, cq, axis=1)      # (B,cq,H,hd)
        if use_window:
            start = jnp.clip(c0 + q_offset - (lk - cq), 0, T - lk)
        else:
            start = 0
        kc = lax.dynamic_slice_in_dim(k, start, lk, axis=1)   # (B,lk,H,hd)
        vc = lax.dynamic_slice_in_dim(v, start, lk, axis=1)
        scores = jnp.einsum("bqhd,bthd->bhqt", qc, kc).astype(jnp.float32)
        scores *= scale
        iabs = c0 + q_offset + jnp.arange(cq)
        jabs = start + jnp.arange(lk)
        mask = jnp.ones((cq, lk), bool)
        if causal:
            mask &= jabs[None, :] <= iabs[:, None]
            if window > 0:
                mask &= jabs[None, :] > iabs[:, None] - window
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqt,bthd->bqhd", probs, vc)
        return out.reshape(B, cq, H, hd_v)

    if n_chunks == 1:
        out = one_chunk(0)
    else:
        out = lax.map(one_chunk, jnp.arange(n_chunks))        # (nc,B,cq,H,hd)
        out = jnp.moveaxis(out, 0, 1).reshape(B, s_pad, H, hd_v)
    return out[:, :S]


def attention(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
              *, causal: bool = True, window: int = 0,
              enc_out: Optional[jax.Array] = None,
              kv_override: Optional[tuple] = None) -> jax.Array:
    """Full-sequence GQA attention (optionally cross-attention)."""
    B, S, D = x.shape
    H, Hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = x @ p["wq"]
    if cfg.attn_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, H, hd)

    src = enc_out if enc_out is not None else x
    k = src @ p["wk"]
    v = src @ p["wv"]
    if cfg.attn_bias:
        k, v = k + p["bk"], v + p["bv"]
    T = src.shape[1]
    k = k.reshape(B, T, Hk, hd)
    v = v.reshape(B, T, Hk, hd)

    if cfg.pos_kind in ("rope", "mrope") and enc_out is None:
        cos, sin = rope_cos_sin(cfg, positions, hd)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    q = hint(q, BATCH, None, MODEL, None)
    out = _attend_chunked(q, k, v, causal=causal and enc_out is None,
                          window=window, q_offset=0, num_kv=Hk)
    out = hint(out, BATCH, None, MODEL, None)
    return out.reshape(B, S, H * hd) @ p["wo"]


def attention_decode(cfg: ModelConfig, p: dict, x: jax.Array, pos: jax.Array,
                     cache: dict, *, window: int = 0) -> tuple[jax.Array, dict]:
    """One-token decode with (ring-buffered, for SWA) KV cache.

    x: (B, 1, D); cache: {"k","v"}: (B, Sc, Hk, hd).  Sc = window for SWA
    layers, max_seq otherwise.  Cached keys are stored rotated."""
    B, _, D = x.shape
    H, Hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    Sc = cache["k"].shape[1]
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, H, hd)
    k = k.reshape(B, 1, Hk, hd)
    v = v.reshape(B, 1, Hk, hd)

    if cfg.pos_kind in ("rope", "mrope"):
        pos_b = jnp.broadcast_to(pos, (B, 1))
        if cfg.pos_kind == "mrope":
            pos_b = jnp.broadcast_to(pos, (3, B, 1))
        cos, sin = rope_cos_sin(cfg, pos_b, hd)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    slot = (pos % Sc).astype(jnp.int32)
    ck = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)

    # abs position held by slot j: pos - ((pos - j) mod Sc); invalid if < 0
    j = jnp.arange(Sc)
    pj = pos - jnp.mod(pos - j, Sc)
    valid = pj >= 0
    if window > 0 and Sc > window:
        valid &= pj > pos - window

    g = H // Hk
    qg = q.reshape(B, Hk, g, hd)
    scores = jnp.einsum("bkgh,btkh->bkgt", qg, ck.astype(q.dtype))
    scores = scores.astype(jnp.float32) / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", probs, cv)
    out = out.reshape(B, 1, H * hd) @ p["wo"]
    return out, {"k": ck, "v": cv}


def cross_attention_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                           cache: dict) -> jax.Array:
    """Decode-time cross attention against precomputed encoder k/v."""
    B = x.shape[0]
    H, Hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    g = H // Hk
    qg = q.reshape(B, Hk, g, hd)
    scores = jnp.einsum("bkgh,btkh->bkgt", qg, cache["xk"].astype(q.dtype))
    scores = scores.astype(jnp.float32) / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", probs, cache["xv"].astype(x.dtype))
    return out.reshape(B, 1, H * hd) @ p["wo"]


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 Multi-head Latent Attention)
# ---------------------------------------------------------------------------

def mla_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                  positions: jax.Array) -> jax.Array:
    """Full-sequence MLA (training/prefill, unabsorbed form)."""
    B, S, D = x.shape
    H = cfg.num_heads
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    cq = x @ p["w_dq"]
    q = (cq @ p["w_uq"]).reshape(B, S, H, nope + rdim)
    q_nope, q_pe = q[..., :nope], q[..., nope:]

    ckv = x @ p["w_dkv"]                                     # (B,S,kvr)
    k_pe = (x @ p["w_kr"]).reshape(B, S, 1, rdim)            # shared across H
    k_nope = (ckv @ p["w_uk"]).reshape(B, S, H, nope)
    v = (ckv @ p["w_uv"]).reshape(B, S, H, vdim)

    cos, sin = rope_cos_sin(cfg, positions, rdim)
    q_pe = apply_rope(q_pe, cos, sin)
    k_pe = apply_rope(k_pe, cos, sin)

    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe, (B, S, H, rdim))], axis=-1)
    q_full = hint(q_full, BATCH, None, MODEL, None)
    k_full = hint(k_full, BATCH, None, MODEL, None)
    v = hint(v, BATCH, None, MODEL, None)
    out = _attend_chunked(q_full, k_full, v, causal=True, window=0,
                          q_offset=0, num_kv=H)
    return out.reshape(B, S, H * vdim) @ p["wo"]


def mla_attention_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                         pos: jax.Array, cache: dict) -> tuple[jax.Array, dict]:
    """Absorbed-form MLA decode: cache holds only (ckv, k_pe) -- the MLA
    cache-compression trick (DeepSeek-V3 §2.1)."""
    B = x.shape[0]
    H = cfg.num_heads
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    Sc = cache["ckv"].shape[1]

    cq = x @ p["w_dq"]
    q = (cq @ p["w_uq"]).reshape(B, H, nope + rdim)
    q_nope, q_pe = q[..., :nope], q[..., nope:]

    ckv_t = x @ p["w_dkv"]                                   # (B,1,kvr)
    kpe_t = (x @ p["w_kr"]).reshape(B, 1, 1, rdim)

    pos_b = jnp.broadcast_to(pos, (B, 1))
    cos, sin = rope_cos_sin(cfg, pos_b, rdim)
    q_pe = apply_rope(q_pe.reshape(B, 1, H, rdim), cos, sin).reshape(B, H, rdim)
    kpe_t = apply_rope(kpe_t, cos, sin).reshape(B, 1, rdim)

    slot = (pos % Sc).astype(jnp.int32)
    ckv = lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_t.astype(cache["ckv"].dtype), slot, axis=1)
    kpe = lax.dynamic_update_slice_in_dim(
        cache["kpe"], kpe_t.astype(cache["kpe"].dtype), slot, axis=1)

    # absorb W_uk into q: q_tilde (B,H,kvr)
    w_uk = p["w_uk"].reshape(kvr, H, nope)
    q_tilde = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk)
    scores = (jnp.einsum("bhr,btr->bht", q_tilde, ckv.astype(q_tilde.dtype))
              + jnp.einsum("bhr,btr->bht", q_pe, kpe.astype(q_pe.dtype)))
    scores = scores.astype(jnp.float32) / jnp.sqrt(
        jnp.asarray(nope + rdim, jnp.float32))
    j = jnp.arange(Sc)
    pj = pos - jnp.mod(pos - j, Sc)
    scores = jnp.where((pj >= 0)[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    attn_c = jnp.einsum("bht,btr->bhr", probs, ckv.astype(x.dtype))  # (B,H,kvr)
    w_uv = p["w_uv"].reshape(kvr, H, vdim)
    out = jnp.einsum("bhr,rhv->bhv", attn_c, w_uv)
    out = out.reshape(B, 1, H * vdim) @ p["wo"]
    return out, {"ckv": ckv, "kpe": kpe}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.mlp_kind == "gelu":
        h = jax.nn.gelu(x @ p["wi"] + p.get("bi", 0))
        h = hint(h, BATCH, None, MODEL)
        return h @ p["wo"]
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    h = hint(h, BATCH, None, MODEL)
    return h @ p["wo"]


def _expert_ffn(cfg: ModelConfig, p: dict, xe: jax.Array) -> jax.Array:
    """xe: (E, C, D) -> (E, C, D) through per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])


def moe(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Token-choice top-k MoE with capacity + scatter dispatch.

    Returns (out, aux_loss).  x: (B, S, D)."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    xf = x.reshape(B * S, D)
    T = B * S

    logits = (xf @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = lax.top_k(probs, k)                          # (T, k)
    topw = topw / (jnp.sum(topw, axis=-1, keepdims=True) + 1e-9)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    tc = min(MOE_CHUNK, T)
    n_chunks = -(-T // tc)
    cap = max(8, int(tc * k / E * cfg.capacity_factor))

    def chunk_fn(args):
        xc, wc, ic = args                                    # (tc,D),(tc,k),(tc,k)
        fi = ic.reshape(-1)                                  # (tc*k,)
        fw = wc.reshape(-1)
        # position of each (token, choice) within its expert, via one-hot cumsum
        oh = jax.nn.one_hot(fi, E, dtype=jnp.int32)          # (tc*k, E)
        pos_mat = jnp.cumsum(oh, axis=0) - 1
        posn = jnp.take_along_axis(pos_mat, fi[:, None], axis=1)[:, 0]
        keep = posn < cap
        slot = jnp.where(keep, fi * cap + posn, E * cap)     # overflow -> dump row
        xrep = jnp.repeat(xc, k, axis=0)                     # (tc*k, D)
        # NOTE (§Perf H2): GSPMD lowers the scatter/gather over the
        # expert-sharded buffer as mask+all-reduce (~14 TB/step at deepseek
        # scale).  Two attempted reformulations (replicated buffer + single
        # all-gather of the expert outputs) measured WORSE under GSPMD's
        # cost model (EXPERIMENTS.md §Perf H2, iters 1-2); the real fix is a
        # shard_map all-to-all token exchange (documented future work).
        buf = jnp.zeros((E * cap + 1, D), xc.dtype).at[slot].add(xrep)
        buf = hint(buf[: E * cap].reshape(E, cap, D), MODEL, None, None)
        ye = _expert_ffn(cfg, p, buf)                        # (E, cap, D)
        ye = hint(ye, MODEL, None, None)
        yrep = ye.reshape(E * cap, D)[jnp.clip(slot, 0, E * cap - 1)]
        yrep = jnp.where(keep[:, None], yrep, 0.0) * fw[:, None].astype(xc.dtype)
        return yrep.reshape(tc, k, D).sum(axis=1)

    if n_chunks == 1:
        out = chunk_fn((xf, topw, topi))
    else:
        t_pad = n_chunks * tc
        xp = jnp.pad(xf, ((0, t_pad - T), (0, 0)))
        wp = jnp.pad(topw, ((0, t_pad - T), (0, 0)))
        ip = jnp.pad(topi, ((0, t_pad - T), (0, 0)))
        out = lax.map(chunk_fn, (xp.reshape(n_chunks, tc, D),
                                 wp.reshape(n_chunks, tc, k),
                                 ip.reshape(n_chunks, tc, k)))
        out = out.reshape(t_pad, D)[:T]

    if cfg.num_shared_experts:
        sh = jax.nn.silu(xf @ p["shared"]["wg"]) * (xf @ p["shared"]["wi"])
        out = out + sh @ p["shared"]["wo"]
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Mamba-1 selective SSM
# ---------------------------------------------------------------------------

def _ssm_scan_chunk(a: jax.Array, b: jax.Array, h0: jax.Array):
    """Associative scan of h_t = a_t * h_{t-1} + b_t within a chunk.

    a, b: (B, L, di, ds); h0: (B, di, ds).  Returns (h_all (B,L,di,ds),
    h_last)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    A, Bc = lax.associative_scan(combine, (a, b), axis=1)
    h_all = A * h0[:, None] + Bc
    return h_all, h_all[:, -1]


def mamba(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Full-sequence Mamba-1 block (chunked selective scan)."""
    B, S, D = x.shape
    di, ds, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    kw = cfg.ssm_conv

    u = x @ p["wx"]                                          # (B,S,di)
    z = x @ p["wz"]
    u = hint(u, BATCH, None, MODEL)

    # causal depthwise conv, width kw
    upad = jnp.pad(u, ((0, 0), (kw - 1, 0), (0, 0)))
    conv = sum(upad[:, i:i + S] * p["conv_w"][i] for i in range(kw))
    u = jax.nn.silu(conv + p["conv_b"])

    xdb = u @ p["x_proj"]                                    # (B,S,dtr+2ds)
    dt = jax.nn.softplus(xdb[..., :dtr] @ p["dt_proj"] + p["dt_bias"])
    Bs = xdb[..., dtr:dtr + ds]                              # (B,S,ds)
    Cs = xdb[..., dtr + ds:]

    A = -jnp.exp(p["a_log"].astype(jnp.float32))             # (di,ds)
    a = jnp.exp(dt[..., None].astype(jnp.float32) * A)       # (B,S,di,ds)
    b = (dt[..., None] * Bs[:, :, None, :] * u[..., None]).astype(jnp.float32)

    lc = min(MAMBA_CHUNK, S)
    n_chunks = -(-S // lc)

    def chunk_step(h0, args):
        ac, bc, cc = args                                    # (B,lc,di,ds) x2, (B,lc,ds)
        h_all, h_last = _ssm_scan_chunk(ac, bc, h0)
        yc = jnp.einsum("blds,bls->bld", h_all, cc)          # (B,lc,di)
        return h_last, yc

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    if n_chunks == 1:
        _, y = chunk_step(h0, (a, b, Cs.astype(jnp.float32)))
    else:
        s_pad = n_chunks * lc
        pad = lambda t: jnp.pad(t, ((0, 0), (0, s_pad - S)) + ((0, 0),) * (t.ndim - 2))
        ax = pad(a).reshape(B, n_chunks, lc, di, ds).swapaxes(0, 1)
        bx = pad(b).reshape(B, n_chunks, lc, di, ds).swapaxes(0, 1)
        cx = pad(Cs.astype(jnp.float32)).reshape(B, n_chunks, lc, ds).swapaxes(0, 1)
        _, y = lax.scan(chunk_step, h0, (ax, bx, cx))
        y = y.swapaxes(0, 1).reshape(B, s_pad, di)[:, :S]

    y = (y + u.astype(jnp.float32) * p["d_skip"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = hint(y, BATCH, None, MODEL)
    return y @ p["out_proj"]


def mamba_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                 cache: dict) -> tuple[jax.Array, dict]:
    """One-token Mamba step.  cache: {"h": (B,di,ds), "conv": (B,kw-1,di)}."""
    B = x.shape[0]
    di, ds, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    kw = cfg.ssm_conv

    u = (x @ p["wx"]).reshape(B, di)
    z = (x @ p["wz"]).reshape(B, di)

    win = jnp.concatenate([cache["conv"], u[:, None]], axis=1)  # (B,kw,di)
    conv = jnp.einsum("bkd,kd->bd", win, p["conv_w"]) + p["conv_b"]
    u = jax.nn.silu(conv)

    xdb = u @ p["x_proj"]
    dt = jax.nn.softplus(xdb[..., :dtr] @ p["dt_proj"] + p["dt_bias"])
    Bs, Cs = xdb[..., dtr:dtr + ds], xdb[..., dtr + ds:]

    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    a = jnp.exp(dt[..., None].astype(jnp.float32) * A)       # (B,di,ds)
    hb = dt[..., None] * Bs[:, None, :] * u[..., None]
    h = a * cache["h"] + hb.astype(jnp.float32)
    y = jnp.einsum("bds,bs->bd", h, Cs.astype(jnp.float32))
    y = (y + u.astype(jnp.float32) * p["d_skip"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"h": h, "conv": win[:, 1:]}
