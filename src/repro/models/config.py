"""Model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    num_heads: int = 0        # 0 => attention-free (pure SSM)
    num_kv_heads: int = 0
    head_dim: int = 0         # 0 => d_model // num_heads
    d_ff: int = 0

    # -- attention options -------------------------------------------------
    attn_bias: bool = False           # Qwen-style QKV bias
    sliding_window: int = 0           # 0 = full attention
    swa_every: int = 1                # SWA on layers where (l % swa_every)!=0
    rope_theta: float = 10000.0
    pos_kind: str = "rope"            # rope | mrope | sinusoidal
    mrope_sections: tuple = (16, 24, 24)  # head_dim split (t, h, w)

    # -- MLA (DeepSeek) -----------------------------------------------------
    mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # -- MLP / MoE ----------------------------------------------------------
    norm_kind: str = "rms"            # rms | ln
    mlp_kind: str = "swiglu"          # swiglu | gelu
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                 # expert hidden dim (defaults to d_ff)
    first_dense_layers: int = 0       # DeepSeek: leading dense layers
    moe_every: int = 1                # MoE on layers where (l % moe_every)==moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01   # load-balance aux loss

    # -- SSM (Mamba-1) / hybrid ----------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0              # 0 => ceil(d_model / 16)
    attn_every: int = 0               # hybrid: one attn layer per this many
    attn_offset: int = 4              # position of the attn layer in a block

    # -- encoder-decoder (Whisper) -------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0              # precomputed frame embeddings (stub)
    cross_attention: bool = False

    # -- modality frontend stubs ----------------------------------------------
    frontend: str = "none"            # none | audio | vision
    num_frontend_tokens: int = 0      # patch embeddings prepended (vision)

    # -- extras ----------------------------------------------------------------
    pad_vocab_to: int = 128           # embedding rows padded to this multiple
    mtp: bool = False                 # DeepSeek multi-token prediction loss
    mtp_weight: float = 0.3
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: Any = jnp.float32          # parameter/activation dtype
    source: str = ""                  # citation for the config

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def moe_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def layer_kinds(self) -> list[tuple[str, str]]:
        """Static per-depth (mixer, mlp) descriptors.

        mixer in {attn, mamba}; mlp in {dense, moe, none}.
        Pure-SSM archs (mamba1) have no separate MLP (mixer includes it).
        """
        kinds = []
        for l in range(self.num_layers):
            if self.arch_type == "ssm":
                kinds.append(("mamba", "none"))
                continue
            if self.attn_every:  # hybrid
                mixer = "attn" if (l % self.attn_every) == self.attn_offset else "mamba"
            elif self.num_heads:
                mixer = "attn"
            else:
                mixer = "mamba"
            if self.num_experts and l >= self.first_dense_layers and \
                    (l % self.moe_every) == self.moe_offset:
                mlp = "moe"
            else:
                mlp = "dense"
            kinds.append((mixer, mlp))
        return kinds

    def scan_blocks(self) -> tuple[int, list[tuple[str, str]]]:
        """(num_blocks, block_pattern): smallest repeating suffix pattern so
        the layer stack is a lax.scan over stacked params (DESIGN §5).
        Leading non-repeating layers (first_dense_layers) are handled
        separately by the model."""
        kinds = self.layer_kinds()[self.first_dense_layers:]
        n = len(kinds)
        for plen in range(1, n + 1):
            if n % plen == 0 and kinds == kinds[:plen] * (n // plen):
                return n // plen, kinds[:plen]
        return 1, kinds

    def uses_swa(self, l: int) -> bool:
        return self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-flops accounting)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)
