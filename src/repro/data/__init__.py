from repro.data.device import DeviceBigramSampler, DeviceGaussianClsSampler
from repro.data.synthetic import (BigramLMData, ClsDataConfig, GaussianClsData,
                                  LMDataConfig, synthetic_lm_batch)
