"""Device-resident federated data sampling.

The host pipeline (``synthetic.BigramLMData.round_batch``) draws every round's
batch with numpy -- a Python loop over sequence positions followed by a
host->device transfer -- which serializes the training loop on the host even
when the round itself is fully jitted.  This module ports the bigram
transition-matrix sampling to pure jnp so a ``lax.scan`` over rounds
(``launch/driver.py``) can draw its own batches on device.

Determinism contract: the tokens of client ``c`` in round ``t`` are a pure
function of ``(t, c, cfg.seed)`` -- the PRNG key is
``fold_in(fold_in(key(seed), t), c)`` and the transition table is fixed at
construction.  In particular the stream of one client does not depend on how
many other clients exist (tests/test_driver.py pins this).

The sampling rule matches the host implementation: token ``s`` is drawn from
the cumulative transition row of token ``s-1`` by counting how many cumsum
entries a uniform variate exceeds (inverse-CDF via comparison).  The PRNG
differs (threefry vs numpy PCG), so device batches are *not* bit-equal to
host batches -- they are the same Markov chain, sampled with a different
stream.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class DeviceBigramSampler:
    """Pure-jnp bigram LM batch sampler for the on-device round driver.

    ``init_state`` returns the device-resident data state (the stacked
    cumulative transition rows) that the driver threads through its scan
    carry (and donates across chunks); ``sample(state, t)`` draws round
    ``t``'s full federated batch shaped ``(G, K, mb, seq)``.
    """
    trans_cum: np.ndarray          # (G, V, V) per-client cumulative rows
    batch_per_client: int
    local_steps: int
    seq_len: int
    vocab_size: int
    num_clients: int
    seed: int

    @classmethod
    def from_data(cls, data, batch_per_client: int,
                  local_steps: int) -> "DeviceBigramSampler":
        """Build from a host ``BigramLMData`` (same transition matrices)."""
        cfg = data.cfg
        cum = np.cumsum(np.stack(data.trans), axis=2).astype(np.float32)
        return cls(trans_cum=cum, batch_per_client=batch_per_client,
                   local_steps=local_steps, seq_len=cfg.seq_len,
                   vocab_size=cfg.vocab_size, num_clients=cfg.num_clients,
                   seed=cfg.seed)

    # -- driver protocol ----------------------------------------------------

    def init_state(self) -> Pytree:
        return {"trans_cum": jnp.asarray(self.trans_cum, jnp.float32)}

    def sample(self, state: Pytree, t: jax.Array) -> tuple[Pytree, Pytree]:
        """Draw round ``t``'s batch: leaves (G, K, mb, seq).  Traceable."""
        cum = state["trans_cum"]
        G, B, S = self.num_clients, self.batch_per_client, self.seq_len
        V = self.vocab_size
        round_key = jax.random.fold_in(jax.random.key(self.seed), t)

        def client_tokens(cum_c, c):
            key = jax.random.fold_in(round_key, c)
            k_first, k_seq = jax.random.split(key)
            first = jax.random.randint(k_first, (B,), 0, V, dtype=jnp.int32)

            def step(prev, k):
                u = jax.random.uniform(k, (B,))
                nxt = jnp.sum(cum_c[prev] < u[:, None], axis=1)
                # float cumsum can top out slightly below 1.0; clamp the
                # (measure-zero) overflow instead of emitting token V
                nxt = jnp.minimum(nxt, V - 1).astype(jnp.int32)
                return nxt, nxt

            _, rest = jax.lax.scan(step, first, jax.random.split(k_seq, S - 1))
            return jnp.concatenate([first[:, None], rest.T], axis=1)  # (B, S)

        toks = jax.vmap(client_tokens)(cum, jnp.arange(G))             # (G,B,S)
        mb = B // self.local_steps
        batch = {"tokens": toks.reshape(G, self.local_steps, mb, S)}
        return state, batch

    # -- convenience --------------------------------------------------------

    def round_batch(self, t) -> Pytree:
        """One round's batch, outside any scan (tests / host-loop parity)."""
        return self.sample(self.init_state(), jnp.asarray(t, jnp.int32))[1]

    def host_round_batch(self, t: int) -> Pytree:
        """The same round's batch drawn the legacy way: a host Python loop
        over clients and sequence positions (one eager PRNG op per step),
        returning numpy.

        Bitwise-identical tokens to ``sample`` (fold_in/split/randint/uniform
        are deterministic per key, vmapped or not), so a host-driven trainer
        fed by this pipeline follows EXACTLY the trajectory of the scanned
        driver while paying the per-round host sampling cost the seed
        pipeline paid -- which is what benchmarks/run.py's fig1/<algo>
        (host-loop) rows measure against fig1/<algo>_scan."""
        G, B, S = self.num_clients, self.batch_per_client, self.seq_len
        V = self.vocab_size
        round_key = jax.random.fold_in(jax.random.key(self.seed),
                                       jnp.asarray(int(t), jnp.int32))
        toks = np.empty((G, B, S), np.int32)
        for c in range(G):
            key = jax.random.fold_in(round_key, c)
            k_first, k_seq = jax.random.split(key)
            prev = np.asarray(jax.random.randint(k_first, (B,), 0, V,
                                                 dtype=jnp.int32))
            toks[c, :, 0] = prev
            ks = jax.random.split(k_seq, S - 1)
            cum_c = self.trans_cum[c]
            for s in range(S - 1):
                u = np.asarray(jax.random.uniform(ks[s], (B,)))
                prev = np.minimum((cum_c[prev] < u[:, None]).sum(axis=1),
                                  V - 1).astype(np.int32)
                toks[c, :, s + 1] = prev
        mb = B // self.local_steps
        return {"tokens": toks.reshape(G, self.local_steps, mb, S)}


@dataclasses.dataclass(frozen=True)
class ShardedSampler:
    """Mesh adapter for the ``init_state()/sample(state, t)`` protocol.

    Delegates to ``base`` and lands the sampled ``(G, K, mb, ...)`` batch on
    the production mesh via a sharding constraint (G over the client axes,
    mb over ``data`` in cross_silo -- see ``launch.train.batch_pspecs``).
    The constraint is pure layout: the tokens are bitwise those of ``base``,
    so mesh trajectories stay comparable to the single-host driver's, and
    GSPMD partitions the per-client sampling computation along the client
    axes instead of materializing the full batch per device.

    Build via ``launch.train.mesh_sampler`` (which derives the shardings
    from the batch's eval_shape); this class stays mesh-agnostic.
    """
    base: Any
    shardings: Any                 # pytree of NamedSharding over the batch

    def init_state(self) -> Pytree:
        return self.base.init_state()

    def sample(self, state: Pytree, t: jax.Array) -> tuple[Pytree, Pytree]:
        state, batch = self.base.sample(state, t)
        return state, jax.lax.with_sharding_constraint(batch, self.shardings)

    def round_batch(self, t) -> Pytree:
        return self.base.round_batch(t)

    def host_round_batch(self, t: int) -> Pytree:
        return self.base.host_round_batch(t)


@dataclasses.dataclass(frozen=True)
class DeviceGaussianClsSampler:
    """Pure-jnp Gaussian-mixture classification sampler for the scan driver.

    Same protocol and determinism contract as ``DeviceBigramSampler``: the
    batch of client ``c`` in round ``t`` is a pure function of
    ``(t, c, seed)`` via ``fold_in(fold_in(key(seed), t), c)``.  Labels are
    drawn from the client's (possibly Dirichlet-skewed) label distribution
    by inverse CDF on the cumulative row -- the same comparison-count trick
    the bigram sampler uses -- and features are the class center plus unit
    Gaussian noise.  ``host_round_batch`` replays the identical PRNG chain
    eagerly per client and is pinned bitwise-equal (tests/test_fed.py), so
    classification workloads ride the scanned driver on exactly the
    trajectory a host-driven trainer would follow.
    """
    centers: np.ndarray            # (C, F) class centers
    label_cum: np.ndarray          # (G, C) per-client cumulative label probs
    batch_per_client: int
    local_steps: int
    num_features: int
    num_classes: int
    num_clients: int
    seed: int

    @classmethod
    def from_data(cls, data, batch_per_client: int,
                  local_steps: int) -> "DeviceGaussianClsSampler":
        """Build from a host ``GaussianClsData`` (same centers/label skew)."""
        cfg = data.cfg
        cum = np.cumsum(np.asarray(data.label_probs, np.float32), axis=1)
        return cls(centers=np.asarray(data.centers, np.float32),
                   label_cum=cum.astype(np.float32),
                   batch_per_client=batch_per_client, local_steps=local_steps,
                   num_features=cfg.num_features, num_classes=cfg.num_classes,
                   num_clients=cfg.num_clients, seed=cfg.seed)

    # -- driver protocol ----------------------------------------------------

    def init_state(self) -> Pytree:
        return {"centers": jnp.asarray(self.centers, jnp.float32),
                "label_cum": jnp.asarray(self.label_cum, jnp.float32)}

    def _client_batch(self, centers, cum_c, key):
        """One client's (B, F) features + (B,) labels from its fold_in key."""
        B, C = self.batch_per_client, self.num_classes
        k_y, k_x = jax.random.split(key)
        u = jax.random.uniform(k_y, (B,))
        y = jnp.minimum(jnp.sum(cum_c[None, :] < u[:, None], axis=1),
                        C - 1).astype(jnp.int32)
        x = centers[y] + jax.random.normal(k_x, (B, self.num_features))
        return x.astype(jnp.float32), y

    def sample(self, state: Pytree, t: jax.Array) -> tuple[Pytree, Pytree]:
        """Draw round ``t``'s batch: x (G, K, mb, F), y (G, K, mb)."""
        G, B, K = self.num_clients, self.batch_per_client, self.local_steps
        round_key = jax.random.fold_in(jax.random.key(self.seed), t)
        x, y = jax.vmap(lambda cum_c, c: self._client_batch(
            state["centers"], cum_c, jax.random.fold_in(round_key, c)))(
                state["label_cum"], jnp.arange(G))
        mb = B // K
        return state, {"x": x.reshape(G, K, mb, self.num_features),
                       "y": y.reshape(G, K, mb)}

    # -- convenience --------------------------------------------------------

    def round_batch(self, t) -> Pytree:
        """One round's batch, outside any scan (tests / host-loop parity)."""
        return self.sample(self.init_state(), jnp.asarray(t, jnp.int32))[1]

    def host_round_batch(self, t: int) -> Pytree:
        """The same batch drawn eagerly per client on the host (numpy out);
        bitwise-identical to ``sample`` -- the classification analogue of
        ``DeviceBigramSampler.host_round_batch``."""
        G, B, K = self.num_clients, self.batch_per_client, self.local_steps
        F = self.num_features
        round_key = jax.random.fold_in(jax.random.key(self.seed),
                                       jnp.asarray(int(t), jnp.int32))
        xs = np.empty((G, B, F), np.float32)
        ys = np.empty((G, B), np.int32)
        centers = jnp.asarray(self.centers, jnp.float32)
        for c in range(G):
            x, y = self._client_batch(centers,
                                      jnp.asarray(self.label_cum[c]),
                                      jax.random.fold_in(round_key, c))
            xs[c], ys[c] = np.asarray(x), np.asarray(y)
        mb = B // K
        return {"x": xs.reshape(G, K, mb, F), "y": ys.reshape(G, K, mb)}
