"""Data pipeline: synthetic federated datasets.

Two task families:
  * LM token streams with a learnable bigram structure (so loss measurably
    decreases during training -- used by examples and integration tests);
  * a classification task (Gaussian mixtures), the analogue of the paper's
    CIFAR-10 / SST-2 setups at laptop scale.

Client partitioning supports uniform (the paper's §5 setup: "split the
training dataset uniformly over 5 clients") and Dirichlet-heterogeneous
splits (standard FL heterogeneity knob).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int = 256
    seq_len: int = 64
    num_clients: int = 5
    heterogeneity: float = 0.0   # 0 = iid; >0 = per-client transition skew
    alpha: float = 0.3           # Dirichlet concentration; lower => more
                                 # predictable chains (lower entropy floor)
    seed: int = 0


class BigramLMData:
    """Markov-chain token generator; each client can get a skewed chain."""

    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        base = rng.dirichlet(np.ones(cfg.vocab_size) * cfg.alpha,
                             size=cfg.vocab_size)
        self.trans = []
        for c in range(cfg.num_clients):
            if cfg.heterogeneity > 0:
                skew = rng.dirichlet(np.ones(cfg.vocab_size) * cfg.alpha,
                                     size=cfg.vocab_size)
                t = (1 - cfg.heterogeneity) * base + cfg.heterogeneity * skew
            else:
                t = base
            self.trans.append(t / t.sum(axis=1, keepdims=True))

    def client_batch(self, client: int, batch_size: int, seed: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((seed, client))
        t = self.trans[client]
        cum = np.cumsum(t, axis=1)
        toks = np.empty((batch_size, cfg.seq_len), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, batch_size)
        for s in range(1, cfg.seq_len):
            u = rng.random(batch_size)
            toks[:, s] = (cum[toks[:, s - 1]] < u[:, None]).sum(axis=1)
        return {"tokens": jnp.asarray(toks)}

    def round_batch(self, batch_per_client: int, local_steps: int,
                    seed: int) -> dict:
        """Batch for one FL round: (G, K, mb, seq)."""
        cfg = self.cfg
        per = [self.client_batch(c, batch_per_client, seed)["tokens"]
               for c in range(cfg.num_clients)]
        toks = jnp.stack(per)                                 # (G, B, S)
        mb = batch_per_client // local_steps
        return {"tokens": toks.reshape(cfg.num_clients, local_steps, mb,
                                       cfg.seq_len)}

    def device_sampler(self, batch_per_client: int, local_steps: int):
        """Pure-jnp sampler over the same transition matrices, usable inside
        a jitted multi-round scan (see repro.data.device)."""
        from repro.data.device import DeviceBigramSampler
        return DeviceBigramSampler.from_data(self, batch_per_client,
                                             local_steps)


@dataclasses.dataclass(frozen=True)
class ClsDataConfig:
    num_features: int = 32
    num_classes: int = 10
    num_clients: int = 5
    dirichlet_alpha: float = 0.0  # 0 = iid label distribution
    seed: int = 0


class GaussianClsData:
    """Gaussian-mixture classification with optional Dirichlet label skew."""

    def __init__(self, cfg: ClsDataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.centers = rng.normal(size=(cfg.num_classes, cfg.num_features)) * 2.0
        if cfg.dirichlet_alpha > 0:
            self.label_probs = rng.dirichlet(
                np.ones(cfg.num_classes) * cfg.dirichlet_alpha,
                size=cfg.num_clients)
        else:
            self.label_probs = np.full(
                (cfg.num_clients, cfg.num_classes), 1.0 / cfg.num_classes)

    def client_batch(self, client: int, batch_size: int, seed: int) -> dict:
        rng = np.random.default_rng((seed, client, 7))
        y = rng.choice(self.cfg.num_classes, size=batch_size,
                       p=self.label_probs[client])
        x = self.centers[y] + rng.normal(size=(batch_size,
                                               self.cfg.num_features))
        return {"x": jnp.asarray(x, jnp.float32), "y": jnp.asarray(y, jnp.int32)}

    def round_batch(self, batch_per_client: int, local_steps: int,
                    seed: int) -> dict:
        per = [self.client_batch(c, batch_per_client, seed)
               for c in range(self.cfg.num_clients)]
        mb = batch_per_client // local_steps
        out = {}
        for k in per[0]:
            v = jnp.stack([p[k] for p in per])
            out[k] = v.reshape(self.cfg.num_clients, local_steps, mb,
                               *v.shape[2:])
        return out

    def device_sampler(self, batch_per_client: int, local_steps: int):
        """Pure-jnp sampler over the same centers/label skew, usable inside
        a jitted multi-round scan (see repro.data.device)."""
        from repro.data.device import DeviceGaussianClsSampler
        return DeviceGaussianClsSampler.from_data(self, batch_per_client,
                                                  local_steps)


def synthetic_lm_batch(key: jax.Array, batch: int, seq: int,
                       vocab: int) -> dict:
    """Pure-random tokens (for shape/dry-run style uses on device)."""
    return {"tokens": jax.random.randint(key, (batch, seq), 0, vocab)}
