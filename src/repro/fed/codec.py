"""Quantized sketch payload codec: real bits on the wire (DESIGN.md §13).

Until this module, ``uplink_bits`` was an accounting fiction: every payload
crossing the (simulated) wire was float32.  The paper's abstract pairs
sketching WITH quantization as the route to both fewer rounds and fewer
per-round bits, and the 1-bit Adam line of work shows adaptive servers
tolerate aggressive payload quantization when paired with error feedback.
Sketch space is the natural place for that stage: every uplink is already a
row of the packed ``(G, b_total)`` payload, so one quantizer covers every
model, and quantization error feeds back in b dims, not d.

The codec sits between the fused sketch and the collective:

    delta --sk--> (b_total,) row --[+EF]--> quantize --> dequantize
          --> faults/sentinels/mask --> the ONE masked mean / psum

* **int8** (``bits=8``): per-row scale ``s = max|row| / 127``, stochastic
  rounding ``q = clip(floor(row/s + u), -127, 127)`` with ``u ~ U[0,1)``,
  decode ``q * s``.  Conditionally unbiased given the row.
* **1-bit** (``bits=1``): per-row scale ``s = max|row|``, sign drawn with
  ``P(+s) = (row/s + 1)/2``, decode ``±s``.  Also conditionally unbiased.
* **Error feedback** (``error_feedback=True``): the residual
  ``e' = (x + e) - Q(x + e)`` is carried per client in sketch space --
  ``(G, b_total)``, living in the scan carry next to the server moments --
  and added before the next round's quantization, so the compression error
  is re-transmitted instead of lost.  Under partial participation an
  unsampled client's memory is frozen (it did not compute this round),
  mirroring the top-k EF baseline's semantics.

**Simulation style**: the payload stays a float32 array HOLDING exactly the
values an int{bits}-plus-f32-scale wire format would reconstruct
(quantize-dequantize in graph); the measured wire size is computed
statically (``CodecConfig.payload_bits``).  Downstream consumers -- faults,
sentinels, the masked mean -- therefore operate on DECODED rows, which is
the honest order: corruption happens in transit to the encoded bytes, and
the server can only vet what it decodes.

**RNG determinism**: the rounding uniforms are a pure function of
``(round_key, codec.seed, global client index)`` via a dedicated fold_in
stream tag, so the streamed ``microbatch=`` fold draws the SAME uniforms
for client c as the materialized path (chunk-split invariance, the
DESIGN.md §12 contract), and scan/host-loop/resume trajectories agree.

**Program families** (DESIGN.md appendix "Pinning methodology"):
``codec=None`` routes at Python level and keeps every existing pinned
trajectory byte-identical; an enabled codec is its own program family
(quantization changes the trajectory by design).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# decorrelates the rounding-uniform stream from the data sampler, fault
# (104729) and delay (7919) fold_in chains -- a distinct prime tag
_CODEC_STREAM_TAG = 15485863


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    """Static payload-codec configuration (binds like ``plan=``/
    ``sentinel=`` via ``functools.partial``, never as a traced kwarg).

    ``bits`` is the mantissa width per payload coordinate: 8 (int8) or 1
    (sign).  ``error_feedback`` carries the per-client quantization
    residual in sketch space across rounds (callers then wrap the server
    state as ``{"opt": ..., "ef": (G, b_total)}``, see
    ``init_codec_state``).  ``seed`` decorrelates the rounding uniforms
    from every other stream."""
    bits: int = 8
    error_feedback: bool = True
    seed: int = 0

    def __post_init__(self):
        assert self.bits in (1, 8), f"bits must be 1 or 8, got {self.bits}"

    def payload_bits(self, b_total: int) -> int:
        """MEASURED uplink bits of ONE encoded payload row: ``bits`` per
        coordinate plus one float32 per-row scale factor.  This is what a
        codec round reports as ``uplink_bits`` (x the effective post-guard
        cohort) in place of the float32 fiction."""
        return int(b_total) * self.bits + 32


def init_codec_state(codec: CodecConfig | None, num_clients: int,
                     b_total: int):
    """The ``(G, b_total)`` sketch-space error-feedback memory (zeros), or
    ``None`` when the codec is off / EF-less (callers then keep the bare
    opt state unwrapped)."""
    if codec is None or not codec.error_feedback:
        return None
    return jnp.zeros((num_clients, b_total), jnp.float32)


def _row_key(codec: CodecConfig, round_key: jax.Array,
             client_id: jax.Array) -> jax.Array:
    k = jax.random.fold_in(round_key, _CODEC_STREAM_TAG)
    k = jax.random.fold_in(k, codec.seed)
    return jax.random.fold_in(k, client_id)


def _quantize_row(codec: CodecConfig, key: jax.Array,
                  row: jax.Array) -> jax.Array:
    """Quantize-dequantize ONE (b,) row with stochastic rounding.

    All-zero rows have scale 0 and decode to exactly 0 (the guards below
    keep 0/0 out of the graph), so a zero-padded streamed tail chunk stays
    exactly zero through the codec."""
    u = jax.random.uniform(key, row.shape, jnp.float32)
    if codec.bits == 1:
        s = jnp.max(jnp.abs(row))
        p = jnp.where(s > 0, (row / jnp.where(s > 0, s, 1.0) + 1.0) * 0.5,
                      0.5)
        return jnp.where(u < p, 1.0, -1.0) * s
    L = float(2 ** (codec.bits - 1) - 1)                   # 127 for int8
    s = jnp.max(jnp.abs(row)) / L
    scaled = jnp.where(s > 0, row / jnp.where(s > 0, s, 1.0), 0.0)
    q = jnp.clip(jnp.floor(scaled + u), -L, L)
    return q * s


def quantize_rows(codec: CodecConfig, round_key: jax.Array, rows: jax.Array,
                  client_ids: jax.Array) -> jax.Array:
    """Quantize-dequantize ``(n, b)`` payload rows; ``client_ids`` are the
    GLOBAL client indices of the rows (so streamed chunks draw the same
    per-client uniforms as the materialized cohort)."""
    return jax.vmap(
        lambda r, c: _quantize_row(codec, _row_key(codec, round_key, c), r)
    )(rows, client_ids)


def encode_decode(codec: CodecConfig, round_key: jax.Array, rows: jax.Array,
                  ef_rows=None, client_ids=None):
    """The full per-round codec stage on ``(n, b)`` payload rows.

    EF residual is added BEFORE quantization and subtracted after
    (``e' = x + e - Q(x + e)``); the decoded rows that go to the server are
    ``Q(x + e)``, so sketch linearity (the streamed-fold argument of
    DESIGN.md §12) still holds per chunk -- the fold sums decoded rows,
    and the sum of decoded rows IS the decoded cohort payload.

    Returns ``(decoded_rows, new_ef_rows)``; ``new_ef_rows`` is ``None``
    when ``ef_rows`` is (the EF-less codec carries no memory)."""
    if client_ids is None:
        client_ids = jnp.arange(rows.shape[0], dtype=jnp.int32)
    x = rows if ef_rows is None else rows + ef_rows
    dec = quantize_rows(codec, round_key, x, client_ids)
    new_ef = (x - dec) if ef_rows is not None else None
    return dec, new_ef


def transmitting_clients(mask) -> jax.Array:
    """Count of clients whose payload is actually billed: strictly-positive
    weight in the EFFECTIVE (post-guard) mask -- the sampled cohort minus
    fault drops and sentinel rejections, the same convention
    ``launch.driver._with_bits`` bills for uncoded rounds."""
    from repro.core.safl import mask_weights
    return jnp.sum((mask_weights(mask) > 0).astype(jnp.float32))


def measured_uplink_bits(codec: CodecConfig, b_total: int,
                         eff_mask=None, num_clients=None) -> jax.Array:
    """Per-round MEASURED uplink bits under the codec: encoded row size x
    the effective transmitting cohort (``eff_mask`` post-guard; ``None``
    bills the full ``num_clients`` cohort)."""
    per_client = jnp.float32(codec.payload_bits(b_total))
    if eff_mask is None:
        return per_client * jnp.float32(num_clients)
    return per_client * transmitting_clients(eff_mask)
