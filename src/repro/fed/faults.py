"""Deterministic, scan-compatible client fault injection (DESIGN.md §10).

The participation layer (``fed/participation.py``) decides who is *sampled*;
this module decides what the sampled clients' payloads look like when they
misbehave.  Three fault families, all applied in sketch space to the
``(G, b_total)`` uplink payload -- the server only ever sees sketches, so
corruption of the transported representation is the honest fault model:

* **dropout-after-compute** -- the client trained and sketched, but its
  payload never arrives (straggler timeout, lost uplink).  Folds into the
  aggregation mask exactly like non-participation.
* **NaN / Inf corruption** -- a client uplinks a poisoned payload (local
  divergence, bit rot in transit).  Without a sentinel this poisons the
  cohort mean; ``fed.robust`` rejects it per-client.
* **Byzantine scaling** -- a client uplinks its sketch scaled by a large
  factor (model-boosting attack, bad local LR).  Finite, so it passes the
  finite-check; the norm-outlier sentinel is what catches it.

Same contract as the participation policies: every draw is a pure function
of ``fold_in(fold_in(fold_in(stream_key, t), c))`` for absolute round index
t and client c, so fault patterns are identical under chunk splits, the
host-loop reference, and ``(t, key)`` cursor resume.

**Transient vs persistent faults.**  By default (``persistent=False``) the
fault stream is keyed off the RUN key (the ``key=`` of ``run_scan`` /
``run_mesh_scan``, threaded here by the driver's ``round_hook_kwargs``).
When the checkpoint-rollback supervisor (``launch/supervisor.py``) retries a
diverged span with a rekeyed run key, the faults are redrawn -- the
transient-fault model where a retry can escape the bad round.
``persistent=True`` keys the stream off the config's own seed only, so the
same faults re-fire on every retry: the model for deterministic poison, and
the test path for the supervisor's bounded-retry exhaustion.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_FAULT_STREAM_TAG = 104729   # decorrelates the fault stream from the data
                             # sampler / cohort / delay fold_in chains

# fault codes for FaultTable rows
OK, DROP, NAN, INF, BYZANTINE = 0, 1, 2, 3, 4


def _spec_from_codes(codes: jax.Array, byzantine_scale: float) -> dict:
    """Lower per-client int fault codes to the traced fault spec.

    The spec is a dict of (G,) arrays consumed by ``corrupt_payload`` /
    ``fold_arrivals``: ``arrive`` (f32 0/1 -- payload reaches the server),
    ``nan``/``inf`` (bool corruption flags) and ``scale`` (f32 multiplier,
    1.0 for honest clients).  A no-fault spec is exactly neutral: multiply
    by 1.0 and ``where(False, ., x)`` are bitwise identities, and an
    all-ones ``arrive`` folds into the mask as ``m * 1.0 = m``.
    """
    codes = codes.astype(jnp.int32)
    return {
        "arrive": (codes != DROP).astype(jnp.float32),
        "nan": codes == NAN,
        "inf": codes == INF,
        "scale": jnp.where(codes == BYZANTINE,
                           jnp.float32(byzantine_scale), jnp.float32(1.0)),
    }


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Bernoulli per-(round, client) fault draws.

    Each client-round draws one uniform u in [0, 1) and lands in the first
    matching interval: ``[0, drop)`` -> dropout-after-compute,
    ``[drop, drop+nan)`` -> NaN payload, then Inf, then Byzantine scaling;
    the remainder is honest.  Faults fire only for rounds in
    ``[start, stop)`` (``stop=None`` = forever) -- a bounded fault window is
    how tests force a mid-run divergence at a known round.
    """
    num_clients: int
    drop_rate: float = 0.0
    nan_rate: float = 0.0
    inf_rate: float = 0.0
    byzantine_rate: float = 0.0
    byzantine_scale: float = 1e3
    start: int = 0
    stop: int | None = None
    persistent: bool = False
    seed: int = 0

    def __post_init__(self):
        assert self.num_clients >= 1
        rates = (self.drop_rate, self.nan_rate, self.inf_rate,
                 self.byzantine_rate)
        assert all(0.0 <= r <= 1.0 for r in rates)
        assert sum(rates) <= 1.0, "fault rates must sum to <= 1"
        assert self.byzantine_scale > 0.0
        assert self.start >= 0
        assert self.stop is None or self.stop >= self.start

    def spec(self, t: jax.Array, base_key: jax.Array) -> dict:
        """The round-t fault spec (see ``_spec_from_codes``); pure in
        (t, client, seed[, base_key]) so scan, host loop and resumed runs
        draw identical faults."""
        if self.persistent:
            key0 = jax.random.fold_in(jax.random.key(self.seed),
                                      _FAULT_STREAM_TAG)
        else:
            key0 = jax.random.fold_in(base_key,
                                      _FAULT_STREAM_TAG + self.seed)
        key_t = jax.random.fold_in(key0, t)
        u = jax.vmap(lambda c: jax.random.uniform(
            jax.random.fold_in(key_t, c)))(jnp.arange(self.num_clients))

        active = t >= self.start
        if self.stop is not None:
            active = active & (t < self.stop)

        d = self.drop_rate
        n = d + self.nan_rate
        i = n + self.inf_rate
        b = i + self.byzantine_rate
        drop = (u < d) & active
        nan = (u >= d) & (u < n) & active
        inf = (u >= n) & (u < i) & active
        byz = (u >= i) & (u < b) & active
        return {
            "arrive": 1.0 - drop.astype(jnp.float32),
            "nan": nan,
            "inf": inf,
            "scale": jnp.where(byz, jnp.float32(self.byzantine_scale),
                               jnp.float32(1.0)),
        }


@dataclasses.dataclass(frozen=True)
class FaultTable:
    """Explicit scripted faults: ``codes[t][c]`` is client c's fault code in
    round t (``faults.OK/DROP/NAN/INF/BYZANTINE``).  Rounds beyond the table
    are fault-free (or wrap, with ``cyclic=True``).  This is the property-
    test workhorse: any fault pattern hypothesis generates is a table."""
    codes: tuple
    byzantine_scale: float = 1e3
    cyclic: bool = False

    def __post_init__(self):
        assert len(self.codes) >= 1
        widths = {len(r) for r in self.codes}
        assert len(widths) == 1, "ragged fault table"
        flat = [c for row in self.codes for c in row]
        assert all(OK <= c <= BYZANTINE for c in flat)
        assert self.byzantine_scale > 0.0

    @property
    def num_clients(self) -> int:
        return len(self.codes[0])

    def spec(self, t: jax.Array, base_key: jax.Array) -> dict:
        del base_key    # scripted faults are persistent by construction
        tbl = jnp.asarray(self.codes, jnp.int32)
        P = tbl.shape[0]
        if self.cyclic:
            row = tbl[jnp.mod(t, P)]
        else:
            # rounds past the script read an appended all-OK row
            tbl = jnp.concatenate(
                [tbl, jnp.zeros((1, self.num_clients), jnp.int32)])
            row = tbl[jnp.minimum(t, P)]
        return _spec_from_codes(row, self.byzantine_scale)


def corrupt_payload(spec: dict, payloads: jax.Array) -> jax.Array:
    """Apply the spec's corruption to a ``(G, b)`` (or shard-local
    ``(G_loc, b)`` with matching spec rows) sketch payload.  Scaling first,
    then NaN/Inf replacement; the no-fault spec is a bitwise identity
    (multiply by 1.0, ``where`` on all-False)."""
    s = payloads * spec["scale"][:, None].astype(payloads.dtype)
    s = jnp.where(spec["nan"][:, None], jnp.asarray(jnp.nan, s.dtype), s)
    s = jnp.where(spec["inf"][:, None], jnp.asarray(jnp.inf, s.dtype), s)
    return s


def take_rows(spec: dict, rows: jax.Array) -> dict:
    """Slice a global (G,) fault spec down to a shard's client rows."""
    return {k: v[rows] for k, v in spec.items()}


def fold_arrivals(spec: dict, part_mask):
    """Fold dropout-after-compute into the aggregation mask: the effective
    weight of a dropped client is 0, exactly as if it had not been sampled.
    Weighted (Horvitz-Thompson) masks keep their static denominator -- a
    dropped draw is a lost sample, the estimator stays unbiased in the
    participation randomness but sees the fault as variance."""
    arrive = spec["arrive"]
    if part_mask is None:
        return arrive
    if isinstance(part_mask, dict):
        return {**part_mask, "w": part_mask["w"] * arrive}
    return part_mask * arrive


def n_dropped(spec: dict, part_mask) -> jax.Array:
    """Count of sampled clients whose payload never arrived this round."""
    from repro.core.safl import mask_weights
    w0 = (jnp.ones_like(spec["arrive"]) if part_mask is None
          else mask_weights(part_mask))
    return jnp.sum((w0 > 0) * (1.0 - spec["arrive"]))
