"""FedBuff-style async staleness buffer for SAFL/SACFL rounds.

Synchronous SAFL applies round t's averaged sketch immediately.  Real
cross-device FL is asynchronous: a client's update lands at the server
seconds-to-rounds after the model it was computed against (FedBuff, Nguyen
et al. 2022).  This module simulates that delayed-gradient regime ON DEVICE,
inside the driver's ``lax.scan``:

* Each round, every (sampled) client sketches its local delta with the
  round's operator as usual; the ``(G, b_total)`` payload is pushed into a
  ring buffer of the last D generation rounds that lives in the donated
  scan carry (``state["buf"]``/``state["bufw"]``).
* A deterministic **delay policy** assigns client c of generation round g a
  delay ``d(g, c) in [0, max_delay]`` -- a pure function of
  ``fold_in(fold_in(key(seed), g), c)``, so arrivals are recomputable at pop
  time and nothing but the payloads needs storing.
* At round t the server pops every payload arriving now (generated at
  ``g = t - d`` with delay exactly d), aggregates arrivals **per generation
  round in sketch space** (Property 1 linearity holds only within one round
  operator), desketches each generation group with ITS OWN operator --
  re-derived from ``fold_in(base_key, g)``, which is why the driver's
  ``buffer=`` hook threads ``t`` and the base key into the round -- and
  applies the staleness-weighted combination

      update = sum_g desk_g( sum_{c arriving} w(d) * sk_g^c / W ),
      w(d) = (1 + d)^(-staleness_alpha),   W = total arrival weight,

  the FedBuff polynomial staleness discount.  A round with no arrivals
  applies a zero pseudo-gradient (the adaptive server still decays its
  moments -- documented behavior, guarded against 0/0).

**Parity pin** (tests/test_fed.py): with ``delay="zero"`` every payload
arrives in its own generation round with weight ``(1+0)^-a = 1.0``, the
buffer reduces to the synchronous masked mean, and the trajectory is
bit-identical to ``safl_round`` under the same keys (the d>0 terms desketch
an exactly-zero payload, which is exact in IEEE addition up to the sign of
zero).  The buffer accumulates in float32, so the pin assumes the default
float32 ``transport_dtype``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.adaptive import apply_update, init_opt_state
from repro.core.clipped import ClippedSAFLConfig, clip_delta
from repro.core.packed import (PackingPlan, derive_generation_params,
                               derive_round_params, desk_flat,
                               sk_packed_clients, unpack_tree)
from repro.core.safl import (SAFLConfig, chunk_clients, client_delta,
                             masked_mean, resolve_microbatch)

Pytree = Any
LossFn = Callable[[Pytree, Any], jax.Array]

_DELAY_STREAM_TAG = 7919   # decorrelates the delay stream from the data
                           # sampler's fold_in(key(seed), t, c) chain


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Staleness-buffer configuration.

    ``max_delay`` is the largest client delay in rounds; the carry buffer
    holds D = max_delay + 1 generation rounds, so every payload arrives
    before its slot is recycled.  ``delay`` picks the deterministic delay
    policy:

    * ``"zero"``    -- every client arrives immediately (the synchronous
                       parity pin);
    * ``"stagger"`` -- client c of generation g is delayed ``(c + g) % D``
                       rounds: deterministic, covers every delay, no RNG;
    * ``"uniform"`` -- iid uniform over [0, max_delay] from the
                       per-(generation, client) fold_in stream.
    """
    max_delay: int = 2
    delay: str = "uniform"          # zero | stagger | uniform
    staleness_alpha: float = 0.5    # w(d) = (1 + d)^-alpha (FedBuff disc.)
    seed: int = 0

    def __post_init__(self):
        assert self.max_delay >= 0
        assert self.delay in ("zero", "stagger", "uniform")
        assert self.staleness_alpha >= 0.0

    @property
    def buffer_rounds(self) -> int:
        return self.max_delay + 1

    def delays(self, g: jax.Array, num_clients: int) -> jax.Array:
        """(G,) int32 delays of generation-round ``g``'s clients; a pure
        traced function of (g, client, seed) -- recomputed identically at
        push and pop, so delays never need to be stored."""
        D = self.buffer_rounds
        clients = jnp.arange(num_clients)
        if self.delay == "zero" or D == 1:
            return jnp.zeros((num_clients,), jnp.int32)
        if self.delay == "stagger":
            return ((clients + g) % D).astype(jnp.int32)
        key_g = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.seed), _DELAY_STREAM_TAG), g)
        return jax.vmap(lambda c: jax.random.randint(
            jax.random.fold_in(key_g, c), (), 0, D, dtype=jnp.int32))(clients)


def arrival_weight(acfg: AsyncConfig, g: jax.Array, d: int,
                   num_clients: int) -> jax.Array:
    """(G,) staleness-discounted arrival weights of generation ``g`` popped
    at delay ``d``: ``1{delay(g, c) == d} * (1 + d)^-alpha``, with
    generations before the run start (g < 0) masked out for d > 0.  The
    d = 0 case REQUIRES ``g = t >= 0`` (the push round itself -- true for
    any caller popping the round it just pushed): guarding it on the
    traced ``g >= 0`` would break the ``delay="zero"`` constant-fold that
    makes the zero-delay round lower to the synchronous program, i.e. the
    bitwise parity pin.  Pure in (g, d, seed) -- the single source of
    the pop predicate, shared by the single-host round below and the mesh
    ring buffer (``launch/train.py``), so both paths pop the exact same
    arrival schedule.  Participation enters multiplicatively: the caller
    multiplies by the generation's stored 0/1 cohort mask, which is exact
    (0/1 factors introduce no rounding)."""
    arrive = acfg.delays(g, num_clients) == d
    if d > 0:
        arrive = arrive & (g >= 0)
    return arrive * ((1.0 + d) ** -acfg.staleness_alpha)


def _split_cfg(cfg) -> tuple[SAFLConfig, ClippedSAFLConfig | None]:
    if isinstance(cfg, ClippedSAFLConfig):
        return cfg.base, cfg
    return cfg, None


def init_async_state(cfg, acfg: AsyncConfig, params: Pytree,
                     plan: PackingPlan, num_clients: int,
                     codec=None) -> dict:
    """Server opt state + the staleness ring buffer (scan-carry resident).

    ``buf[g % D]`` holds generation g's per-client sketch payloads
    ``(G, b_total)`` for the D most recent generations; ``bufw`` the
    matching participation weights (0 for unsampled clients).  ``cfg`` is a
    ``SAFLConfig`` or (for SACFL) a ``ClippedSAFLConfig``.  ``codec`` (a
    ``fed.codec.CodecConfig`` with ``error_feedback``) adds the per-client
    sketch-space EF memory under ``"ef"`` -- pass the same codec to
    ``make_async_round``."""
    base, _ = _split_cfg(cfg)
    D = acfg.buffer_rounds
    state = {"opt": init_opt_state(base.server, params),
             "buf": jnp.zeros((D, num_clients, plan.b_total), jnp.float32),
             "bufw": jnp.zeros((D, num_clients), jnp.float32)}
    from repro.fed.codec import init_codec_state
    ef = init_codec_state(codec, num_clients, plan.b_total)
    if ef is not None:
        state["ef"] = ef
    return state


def make_async_round(cfg, loss_fn: LossFn, acfg: AsyncConfig,
                     plan: PackingPlan, microbatch=None, codec=None):
    """Build the async round function for the driver's ``buffer=`` hook.

    ``cfg`` is a ``SAFLConfig``, or a ``ClippedSAFLConfig`` to run the
    client half with SACFL's clipped deltas (heavy-tail setting).

    ``microbatch`` (static) streams the client-delta + sketch stage over
    chunks of that many clients (DESIGN.md §12): each chunk's rows land at
    their GLOBAL client offsets in the staged ``(G, b_total)`` payload, so
    the ring push/pop -- whose storage is inherently O(D * G * b_total) --
    is unchanged, but the ``(G, d_total)`` delta stack never materializes.
    ``None`` / >= G keeps the materialized path (and its bitwise pins)
    untouched.  The driver threads the knob via ``functools.partial``
    (``run_scan(..., microbatch=)``), which binds it to this fn's keyword.

    ``codec`` (static ``fed.codec.CodecConfig``, DESIGN.md §13) quantizes
    each generation's payload rows BEFORE the sentinel vetting and the ring
    push, so the buffer stores QUANTIZED (decoded) generations and every
    later pop re-emits exactly what crossed the wire.  With
    ``codec.error_feedback`` the state carries the per-client EF memory
    under ``"ef"`` (``init_async_state(..., codec=)``); unsampled clients
    freeze theirs, while fault-dropped / sentinel-rejected clients still
    update it (the loss happened in transit, after encoding).  A codec
    round reports the MEASURED ``uplink_bits``.

    Signature of the returned fn (driver-compatible plus the buffer kwargs
    the hook supplies):

        round_fn(params, state, batch, round_key, *, t, base_key,
                 part_mask=None) -> (params, state, metrics)

    ``t`` is the traced round index (ring-buffer arithmetic + delay policy);
    ``base_key`` is the run key, from which generation round g's sketch
    operator is re-derived as ``fold_in(base_key, g)`` when its delayed
    payload is desketched."""
    base, clip = _split_cfg(cfg)
    D = acfg.buffer_rounds

    def round_fn(params, state, batch, round_key, *, t, base_key,
                 part_mask=None, lr_scale=1.0, fault_spec=None,
                 sentinel=None, microbatch=microbatch):
        eta = jnp.asarray(base.client_lr, jnp.float32)

        def one_client(mb):
            delta, l = client_delta(base, loss_fn, params, mb, eta)
            return (clip_delta(clip, delta), l) if clip is not None \
                else (delta, l)

        mbv = resolve_microbatch(microbatch,
                                 jax.tree.leaves(batch)[0].shape[0])
        if mbv is None:
            deltas, losses = jax.vmap(one_client)(batch)
            G = jax.tree.leaves(deltas)[0].shape[0]
        else:
            G = jax.tree.leaves(batch)[0].shape[0]
        from repro.fed.participation import is_weighted_mask
        if is_weighted_mask(part_mask):
            raise TypeError(
                "the async staleness buffer stores 0/1 cohort masks per "
                "generation; weighted (importance-sampling) masks are not "
                "supported -- use a 0/1 participation policy")
        mask = jnp.ones((G,), jnp.float32) if part_mask is None else part_mask

        # -- push: generation t's payloads claim slot t % D (its previous
        # tenant, generation t - D, fully drained by round t - 1).  Faults
        # corrupt the payload and sentinels vet it BEFORE the push (DESIGN.md
        # §10): the buffer must never store a poisoned row, or it would
        # re-emit it at every later pop of that generation; a dropped or
        # rejected client stores weight 0, exactly like non-participation. --
        rp_t = derive_round_params(plan, round_key)
        if mbv is None:
            sks = sk_packed_clients(plan, rp_t, deltas).astype(jnp.float32)
        else:
            # streamed staging (DESIGN.md §12): the scan's stacked ys land
            # each chunk's sketch rows at their global client offsets; the
            # tail-pad rows are sliced off before anything consumes them
            n_mb = -(-G // mbv)
            bc = chunk_clients(batch, mbv, n_mb * mbv - G)

            def sk_chunk(carry, b1):
                d, l = jax.vmap(one_client)(b1)
                return carry, (sk_packed_clients(plan, rp_t, d)
                               .astype(jnp.float32), l)

            _, (sks_c, losses_c) = jax.lax.scan(sk_chunk, 0, bc)
            sks = sks_c.reshape(n_mb * mbv, -1)[:G]
            losses = losses_c.reshape(-1)[:G]
        # -- codec (DESIGN.md §13): quantize + EF on the full staged
        # (G, b_total) payload, before vetting and before the push -- the
        # ring stores quantized generations.  Staging happens after the
        # streamed fold here, so both mbv branches share this stage (and
        # trivially agree).  Unsampled clients (pre-guard mask 0) freeze
        # their EF memory; guard drops/rejections happen in transit AFTER
        # encoding, so those clients still update theirs. --
        new_ef = None
        if codec is not None:
            from repro.fed.codec import encode_decode
            if "ef" in state:
                dec, ef_upd = encode_decode(codec, round_key, sks,
                                            ef_rows=state["ef"])
                new_ef = jnp.where((mask > 0)[:, None], ef_upd, state["ef"])
            else:
                dec, _ = encode_decode(codec, round_key, sks)
            sks = dec
        counters = {}
        if fault_spec is not None or sentinel is not None:
            from repro.fed.robust import guard_uplink
            sks, mask, counters = guard_uplink(
                sks, mask, fault_spec, sentinel)
        slot_t = jnp.mod(t, D)
        buf = state["buf"].at[slot_t].set(sks)
        bufw = state["bufw"].at[slot_t].set(mask)

        # -- pop: arrivals are recomputed, not stored.  Client c of
        # generation g = t - d arrives now iff its delay is exactly d; each
        # generation group is summed in ITS OWN sketch space, then
        # desketched with ITS OWN round operator.  The d = 0 group reads the
        # values just pushed, so it uses ``sks``/``mask`` directly (common
        # subexpression; buf[slot_t] holds exactly these arrays), keeping the
        # d = 0 data path op-for-op the synchronous one.  With the "zero"
        # delay policy the d > 0 arrival predicates are compile-time False,
        # so those terms constant-fold away and the whole round lowers to
        # the synchronous program -- the bitwise parity pin. --
        weighted = []                     # (W_d, S_d, rp_g) per delay
        for d in range(D):                # static: D is a config constant
            g = t - d
            if d == 0:
                payload, w_in = sks, mask
            else:
                payload = buf[jnp.mod(g, D)]
                w_in = bufw[jnp.mod(g, D)]
            if acfg.delay == "zero" and d > 0:
                continue                  # statically empty arrival group
            w = w_in * arrival_weight(acfg, g, d, G)
            S_d = jnp.sum(w[:, None] * payload, axis=0)
            rp_g = rp_t if d == 0 else derive_generation_params(
                plan, base_key, g)
            weighted.append((jnp.sum(w), S_d, rp_g))

        W = sum(wd for wd, _, _ in weighted)
        W_safe = jnp.where(W > 0, W, 1.0)   # no arrivals -> zero update
        update_flat = sum(desk_flat(plan, rp_g, S_d / W_safe)
                          for _, S_d, rp_g in weighted)
        update = unpack_tree(plan, update_flat)

        new_params, opt = apply_update(base.server, state["opt"], params,
                                       update, lr_scale=lr_scale)
        loss = masked_mean(losses, part_mask)
        if sentinel is not None:
            # a no-arrival round under sentinels carries the server through
            # unchanged (the zero-pseudo-gradient legacy semantics would
            # still decay the adaptive moments); W is the scalar select.
            from repro.fed.robust import divergence_flag
            new_params, opt = jax.tree.map(
                lambda n, o: jnp.where(W > 0, n, o),
                (new_params, opt), (params, state["opt"]))
            counters = {**counters,
                        "diverged": divergence_flag(sentinel, loss)}
        metrics = {"loss": loss, "arrival_weight": W, **counters}
        if codec is not None:
            from repro.fed.codec import measured_uplink_bits
            metrics["uplink_bits"] = measured_uplink_bits(
                codec, plan.b_total, eff_mask=mask)
        new_state = {"opt": opt, "buf": buf, "bufw": bufw}
        if new_ef is not None:
            # deliberately outside the sentinel no-arrival select above:
            # EF tracks what each client TRANSMITTED this round, and a
            # no-arrival round still transmitted (the ring holds it)
            new_state["ef"] = new_ef
        return new_params, new_state, metrics

    return round_fn
