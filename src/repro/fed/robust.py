"""Sketch-space payload sentinels: graceful degradation before aggregation.

The server's one structural advantage over arbitrary client misbehavior is
that every uplink arrives in the SAME compressed representation -- a row of
the ``(G, b_total)`` packed sketch payload.  That makes per-client
validation O(G * b_total), independent of the model dimension d, and lets
rejection reuse the participation machinery: a rejected client is folded
into the ``masked_mean`` / ``masked_psum_mean`` mask with weight 0, so the
mesh path still pays exactly one payload-sized psum (DESIGN.md §10).

Fusion order (the §10 contract): **faults -> sentinels -> participation
mask -> one psum**.  Faults corrupt the payload and knock dropped clients
out of the mask (``fed.faults``); the sentinels then

1. **finite-check** each payload row and zero rejected rows (``masked_mean``
   computes ``sum(x * m)``, and IEEE ``0 * NaN = NaN`` -- masking alone does
   NOT contain a poisoned row, the payload must be zeroed too);
2. optionally reject **norm outliers**: rows whose squared sketch norm
   exceeds ``norm_mult^2`` times the cohort's (lower) median squared norm --
   by sketch norm preservation (the paper's subspace embedding property,
   DESIGN.md §1) an honestly-scaled delta cannot blow up its sketch, so a
   Byzantine-scaled payload is visible in sketch space.  Median-based, so it
   tolerates strictly less than half the cohort misbehaving (the classic
   breakdown point);
3. carry server params/opt through UNCHANGED when the surviving cohort is
   empty (an all-zero masked mean is NOT a no-op for an adaptive server:
   moment decay would still move the iterate);
4. flag **loss divergence** (non-finite, or above ``divergence``) into the
   chunked metric history -- the signal the rollback supervisor
   (``launch/supervisor.py``) watches, alongside the per-round
   ``n_dropped`` / ``n_rejected`` counters.

Neutrality (tests/test_faults.py): with no faults injected and finite
payloads, every sentinel op is an ELEMENTWISE identity (``m * 1.0``,
``where(True, x, .)``) -- but the extra ``diverged``/counter outputs change
the round's output structure, which is enough to shift XLA's fusion
choices, so a sentinel-enabled clean run matches the unguarded trajectory
to float32 ulps, not bitwise (empirically, even duplicating ``loss`` as a
second output perturbs the compiled reduction order).  What IS bitwise: a
disabled sentinel (``sentinel=None`` leaves the program untouched), a
neutral fault spec alone (all rates 0 -- verified bit-for-bit against the
hookless scan), and any comparison WITHIN the guarded program family --
e.g. a NaN-corrupted client round equals the same round with that client
drop-masked, bit for bit, because both sides compile the same program.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.safl import mask_weights


@dataclasses.dataclass(frozen=True)
class SentinelConfig:
    """``norm_mult=0`` disables norm-outlier rejection (finite-checks are
    always on -- they are the point of the layer).  ``divergence=0`` flags
    only non-finite losses; a positive threshold also flags loss blow-ups,
    which is how the supervisor catches runs that diverge while staying
    finite."""
    norm_mult: float = 10.0
    divergence: float = 0.0

    def __post_init__(self):
        assert self.norm_mult >= 0.0
        assert self.divergence >= 0.0


def masked_median(x: jax.Array, pool: jax.Array) -> jax.Array:
    """Lower median of ``x`` restricted to ``pool`` (bool mask).  Sort with
    non-pool entries pushed to +inf, then index ``(n_pool - 1) // 2`` --
    deterministic, no interpolation, +inf on an empty pool (which makes the
    norm test vacuously pass; an empty pool has no weight anyway)."""
    srt = jnp.sort(jnp.where(pool, x, jnp.inf))
    n = jnp.sum(pool).astype(jnp.int32)
    return srt[jnp.maximum(n - 1, 0) // 2]


def _valid_rows(scfg: SentinelConfig, payloads: jax.Array,
                w_arr: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row acceptance verdicts and the finite-zeroed payload.

    ``w_arr`` is the post-arrival weight vector (participation x arrivals);
    the norm-outlier median pools only arrived, finite, sampled rows, so a
    rejected-by-NaN round and the same round with that client dropped see
    the SAME median -- the bitwise NaN==drop property relies on this."""
    ok = jnp.isfinite(payloads).all(axis=-1)
    clean = jnp.where(ok[:, None], payloads, jnp.asarray(0.0, payloads.dtype))
    valid = ok
    if scfg.norm_mult > 0.0:
        nrm2 = jnp.sum(jnp.square(clean), axis=-1)
        pool = (w_arr > 0) & ok
        med2 = masked_median(nrm2, pool)
        valid = valid & (nrm2 <= scfg.norm_mult ** 2 * med2)
    return valid, clean


def _fold_valid(part_mask, valid: jax.Array):
    v = valid.astype(jnp.float32)
    if part_mask is None:
        return v
    if isinstance(part_mask, dict):
        return {**part_mask, "w": part_mask["w"] * v}
    return part_mask * v


def mask_wsum(mask) -> jax.Array:
    """Total surviving cohort weight (scalar) of an effective mask."""
    return jnp.sum(mask_weights(mask))


def guard_uplink(payloads: jax.Array, part_mask, fault_spec,
                 sentinel: SentinelConfig | None):
    """Apply the §10 fusion chain to a full ``(G, b_total)`` payload.

    Returns ``(payloads, eff_mask, counters)`` where ``eff_mask`` is the
    participation mask with fault drops and sentinel rejections folded in
    (weight 0) and ``counters = {"n_dropped", "n_rejected"}``.  The caller
    aggregates with the ONE existing masked mean -- no extra collective.
    """
    counters = {}
    if fault_spec is not None:
        from repro.fed.faults import (corrupt_payload, fold_arrivals,
                                      n_dropped)
        counters["n_dropped"] = n_dropped(fault_spec, part_mask)
        payloads = corrupt_payload(fault_spec, payloads)
        part_mask = fold_arrivals(fault_spec, part_mask)
    if sentinel is not None:
        w_arr = (jnp.ones((payloads.shape[0],), jnp.float32)
                 if part_mask is None else mask_weights(part_mask))
        valid, payloads = _valid_rows(sentinel, payloads, w_arr)
        counters["n_rejected"] = jnp.sum((w_arr > 0) & ~valid)
        part_mask = _fold_valid(part_mask, valid)
    return payloads, part_mask, counters


def carry_if_empty(eff_mask, new: tuple, old: tuple) -> tuple:
    """Empty-cohort fallback: if no client survived the mask fusion, keep
    the old (params, opt) trees -- the scalar select is a ``where``, so the
    non-empty path is untouched (``where(False, old, new) = new`` exactly).
    """
    empty = mask_wsum(eff_mask) == 0
    return jax.tree.map(lambda n, o: jnp.where(empty, o, n), new, old)


def divergence_flag(scfg: SentinelConfig, loss: jax.Array) -> jax.Array:
    """0/1 loss-divergence sentinel for the metric history."""
    bad = ~jnp.isfinite(loss)
    if scfg.divergence > 0.0:
        bad = bad | (loss > scfg.divergence)
    return bad.astype(jnp.float32)


def sentinel_validity(scfg: SentinelConfig, payload_loc: jax.Array,
                      rows: jax.Array, w_arr: jax.Array, num_clients: int,
                      all_axes) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Shard-local sentinel verdicts with GLOBALLY consistent validity.

    Inside ``shard_map`` each device holds a ``(G_loc, b_loc)`` slice of the
    payload -- its client rows ``rows`` and one model-parallel chunk of each
    row.  A client is finite only if EVERY chunk is finite, and its sketch
    norm is the sum of per-chunk norms, so the verdict needs one psum of two
    tiny ``(G,)`` stats arrays over ALL mesh axes (client axes merge
    disjoint row sets; model axes combine chunks of the same row).  Without
    this cross-model-shard agreement, different shards would divide by
    different surviving-cohort weights and desynchronize the model.

    Returns ``(valid (G,), clean_loc, n_rejected)``; ``valid`` and the
    rejection count are identical on every device, the payload slice has its
    locally non-finite rows zeroed (rows bad only on OTHER shards get weight
    0 from ``valid``, which suffices -- their local slice is finite).
    """
    ok_loc = jnp.isfinite(payload_loc).all(axis=-1)
    clean_loc = jnp.where(ok_loc[:, None],
                          payload_loc, jnp.asarray(0.0, payload_loc.dtype))
    bad = jnp.zeros((num_clients,), jnp.float32).at[rows].add(
        (~ok_loc).astype(jnp.float32))
    nrm2 = jnp.zeros((num_clients,), jnp.float32).at[rows].add(
        jnp.sum(jnp.square(clean_loc), axis=-1))
    if all_axes:
        bad, nrm2 = jax.lax.psum((bad, nrm2), all_axes)
    valid = bad == 0
    if scfg.norm_mult > 0.0:
        pool = (w_arr > 0) & valid
        med2 = masked_median(nrm2, pool)
        valid = valid & (nrm2 <= scfg.norm_mult ** 2 * med2)
    n_rejected = jnp.sum((w_arr > 0) & ~valid)
    return valid, clean_loc, n_rejected
