"""Client participation policies: who reports in round t.

The paper's setting has all N clients uplink every round; real federated
deployments do not (FetchSGD, Rothchild et al. 2020; FedBuff, Nguyen et al.
2022).  A *participation policy* decides the round-t cohort and emits a
``(num_clients,)`` 0/1 mask that the round functions consume as
``part_mask`` -- the server mean over the packed ``(G, b_total)`` sketch
payload (and over baseline deltas / error-feedback state) then divides by
the SAMPLED cohort size (``core.safl.masked_mean``).

Design constraints (DESIGN.md §7):

* **Scannable.**  ``mask(t)`` is a pure traced function of the round index,
  so the on-device driver (``launch/driver.py``) evaluates it inside its
  ``lax.scan`` body; nothing about participation leaves the device.
* **Bit-reproducible.**  Randomized cohorts derive from
  ``fold_in(fold_in(key(seed), t), c)`` -- the same per-(round, client)
  stream discipline the device data sampler uses -- so the mask of round t
  is independent of chunking, of previous rounds, and of how the run is
  resumed.
* **Never empty.**  Every policy guarantees >=1 sampled client per round
  (asserted at construction); the masked-mean denominator therefore never
  hits the max() guard, and an all-ones mask reproduces the
  full-participation path bitwise.

In simulation all G clients still *compute* (static shapes under vmap/scan);
the mask governs what the server aggregates -- standard FL-simulation
semantics (unsampled work is discarded, matching a real deployment where it
was never run).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# re-exported for convenience: the aggregation helpers live in core so the
# round families can use them without importing repro.fed
from repro.core.safl import masked_mean, masked_mean_tree  # noqa: F401


@dataclasses.dataclass(frozen=True)
class UniformParticipation:
    """Uniform-without-replacement cohort of fixed size m per round.

    Client c's round-t variate is ``uniform(fold_in(fold_in(key(seed), t),
    c))``; the cohort is the m smallest variates -- exactly m clients, no
    replacement, and each client's stream is independent of N (the variate
    of client c never changes when clients are added).
    """
    num_clients: int
    frac: float = 0.25          # sampled fraction; cohort m = round(frac*N)
    seed: int = 0

    def __post_init__(self):
        assert self.num_clients >= 1
        assert 0.0 < self.frac <= 1.0, f"frac {self.frac} not in (0, 1]"
        assert self.cohort_size >= 1, "policy must sample >=1 client"

    @property
    def cohort_size(self) -> int:
        return max(1, int(round(self.frac * self.num_clients)))

    def mask(self, t: jax.Array) -> jax.Array:
        key_t = jax.random.fold_in(jax.random.key(self.seed), t)
        u = jax.vmap(lambda c: jax.random.uniform(
            jax.random.fold_in(key_t, c)))(jnp.arange(self.num_clients))
        order = jnp.argsort(u)
        return jnp.zeros((self.num_clients,), jnp.float32).at[
            order[:self.cohort_size]].set(1.0)


@dataclasses.dataclass(frozen=True)
class FixedCohort:
    """A static cohort: the same client subset reports every round."""
    num_clients: int
    clients: tuple[int, ...] = (0,)

    def __post_init__(self):
        assert len(self.clients) >= 1, "policy must sample >=1 client"
        assert all(0 <= c < self.num_clients for c in self.clients)

    @property
    def cohort_size(self) -> int:
        return len(set(self.clients))

    def mask(self, t: jax.Array) -> jax.Array:
        m = np.zeros((self.num_clients,), np.float32)
        m[list(self.clients)] = 1.0
        return jnp.asarray(m)


@dataclasses.dataclass(frozen=True)
class AvailabilityTrace:
    """Cyclic availability: round t's cohort is row ``t % P`` of a fixed
    (P, num_clients) 0/1 trace -- diurnal/charging-window availability at
    simulation scale.  ``round_robin`` builds the canonical cyclic split
    where client c is available iff ``c % groups == t % groups``."""
    trace: tuple[tuple[float, ...], ...]     # (P, N) rows of 0/1

    def __post_init__(self):
        assert len(self.trace) >= 1
        n = len(self.trace[0])
        assert all(len(row) == n for row in self.trace)
        assert all(sum(row) >= 1 for row in self.trace), \
            "every trace row must have >=1 available client"

    @classmethod
    def round_robin(cls, num_clients: int, groups: int) -> "AvailabilityTrace":
        assert 1 <= groups <= num_clients
        rows = tuple(tuple(1.0 if c % groups == g else 0.0
                           for c in range(num_clients))
                     for g in range(groups))
        return cls(trace=rows)

    @property
    def num_clients(self) -> int:
        return len(self.trace[0])

    @property
    def cohort_size(self) -> int:
        """Largest per-round cohort (upper bound for bits accounting)."""
        return int(max(sum(row) for row in self.trace))

    def mask(self, t: jax.Array) -> jax.Array:
        trace = jnp.asarray(self.trace, jnp.float32)
        return trace[jnp.mod(t, trace.shape[0])]


@dataclasses.dataclass(frozen=True)
class FullParticipation:
    """All N clients every round -- the paper's setting, as a policy.  Its
    all-ones mask routes through the masked aggregation path and is pinned
    bitwise-equal to passing no mask at all (tests/test_fed.py)."""
    num_clients: int

    def __post_init__(self):
        assert self.num_clients >= 1

    @property
    def cohort_size(self) -> int:
        return self.num_clients

    def mask(self, t: jax.Array) -> jax.Array:
        return jnp.ones((self.num_clients,), jnp.float32)
