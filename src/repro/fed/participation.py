"""Client participation policies: who reports in round t.

The paper's setting has all N clients uplink every round; real federated
deployments do not (FetchSGD, Rothchild et al. 2020; FedBuff, Nguyen et al.
2022).  A *participation policy* decides the round-t cohort and emits a
``(num_clients,)`` 0/1 mask that the round functions consume as
``part_mask`` -- the server mean over the packed ``(G, b_total)`` sketch
payload (and over baseline deltas / error-feedback state) then divides by
the SAMPLED cohort size (``core.safl.masked_mean``).

Design constraints (DESIGN.md §7):

* **Scannable.**  ``mask(t)`` is a pure traced function of the round index,
  so the on-device driver (``launch/driver.py``) evaluates it inside its
  ``lax.scan`` body; nothing about participation leaves the device.
* **Bit-reproducible.**  Randomized cohorts derive from
  ``fold_in(fold_in(key(seed), t), c)`` -- the same per-(round, client)
  stream discipline the device data sampler uses -- so the mask of round t
  is independent of chunking, of previous rounds, and of how the run is
  resumed.
* **Never empty.**  Every policy guarantees >=1 sampled client per round
  (asserted at construction); the masked-mean denominator therefore never
  hits the max() guard, and an all-ones mask reproduces the
  full-participation path bitwise.

In simulation all G clients still *compute* (static shapes under vmap/scan);
the mask governs what the server aggregates -- standard FL-simulation
semantics (unsampled work is discarded, matching a real deployment where it
was never run).

Two mask forms exist: plain ``(num_clients,)`` 0/1 arrays (cohort mean
divides by the sampled count), and the *weighted* dict form
``{"w", "den", "n"}`` emitted by ``ImportanceParticipation`` (Horvitz-
Thompson numerator weights with a static denominator; see
``core.safl.masked_mean``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# re-exported for convenience: the aggregation helpers live in core so the
# round families can use them without importing repro.fed
from repro.core.safl import masked_mean, masked_mean_tree  # noqa: F401


def is_weighted_mask(mask) -> bool:
    """True for the *weighted* dict mask form (``{"w", "den", "n"}``) emitted
    by ``ImportanceParticipation``.

    The async staleness buffers (single-host ``fed.async_buffer`` and the
    mesh ring buffer in ``launch/train.py``) store plain 0/1 cohort masks
    per generation and use this predicate to reject weighted masks at trace
    time with one consistent error."""
    return isinstance(mask, dict)


def check_policy_clients(policy, num_clients: int, where: str) -> None:
    """Fail fast when a policy's client universe does not match the driver's.

    A mismatched ``num_clients`` would silently sample cohorts over the
    wrong index set (the mask is positional).  The mesh driver calls this
    at build time (it knows G from the mesh topology); the single-host
    driver cannot -- it learns G only from the batch shape at trace time,
    where a mismatch surfaces as a broadcast error in ``masked_mean``."""
    n = getattr(policy, "num_clients", None)
    if n is not None and int(n) != int(num_clients):
        raise ValueError(
            f"{where}: participation policy covers {n} clients but the "
            f"driver runs {num_clients} -- build the policy with "
            f"num_clients={num_clients}")


def round_variates(num_clients: int, seed: int, t) -> jax.Array:
    """Per-(round, client) uniforms shared by the randomized policies.

    ``u_c = uniform(fold_in(fold_in(key(seed), t), c))`` -- a pure function
    of ``(t, c, seed)``; in particular client c's variate is independent of
    how many other clients exist (the same stream discipline the device data
    sampler uses), which tests/test_properties.py pins."""
    key_t = jax.random.fold_in(jax.random.key(seed), t)
    return jax.vmap(lambda c: jax.random.uniform(
        jax.random.fold_in(key_t, c)))(jnp.arange(num_clients))


@dataclasses.dataclass(frozen=True)
class UniformParticipation:
    """Uniform-without-replacement cohort of fixed size m per round.

    Client c's round-t variate is ``uniform(fold_in(fold_in(key(seed), t),
    c))``; the cohort is the m smallest variates -- exactly m clients, no
    replacement, and each client's stream is independent of N (the variate
    of client c never changes when clients are added).
    """
    num_clients: int
    frac: float = 0.25          # sampled fraction; cohort m = round(frac*N)
    seed: int = 0

    def __post_init__(self):
        assert self.num_clients >= 1
        assert 0.0 < self.frac <= 1.0, f"frac {self.frac} not in (0, 1]"
        assert self.cohort_size >= 1, "policy must sample >=1 client"

    @property
    def cohort_size(self) -> int:
        return max(1, int(round(self.frac * self.num_clients)))

    def variates(self, t: jax.Array) -> jax.Array:
        """The policy's per-client round-t uniforms (``round_variates``)."""
        return round_variates(self.num_clients, self.seed, t)

    def mask(self, t: jax.Array) -> jax.Array:
        order = jnp.argsort(self.variates(t))
        return jnp.zeros((self.num_clients,), jnp.float32).at[
            order[:self.cohort_size]].set(1.0)


@dataclasses.dataclass(frozen=True)
class ImportanceParticipation:
    """Non-uniform client sampling with 1/(N p_c) importance reweighting.

    Sampling is the exponential race (Efraimidis--Spirakis weighted sampling
    without replacement): the round-t cohort is the m smallest keys
    ``z_c = -log1p(-u_c) / (N p_c)`` over the SAME per-(round, client)
    uniforms ``u_c`` that ``UniformParticipation`` draws.  A larger ``p_c``
    shrinks client c's key, so it is sampled more often; at m = 1 the
    inclusion probability is exactly ``p_c``.

    The emitted mask is the *weighted* form consumed by
    ``core.safl.masked_mean``:

        ``{"w": 1{c in S} / (N p_c), "den": m, "n": m}``

    i.e. the Horvitz-Thompson estimator ``sum_{c in S} x_c / (N p_c m)``
    with the static denominator m (NOT the random weight sum -- that would
    be a biased ratio estimator).  It is unbiased under the Poisson
    approximation ``pi_c ~= m p_c`` (exact at m = 1 and under uniform
    probabilities, where every weight is exactly 1.0) and corrects the
    systematic under-representation of low-probability clients that the
    unweighted cohort mean suffers (tests/test_fed.py measures both).

    Validity regime: the approximation needs ``m * max(p_c) <= 1`` --
    beyond it an inclusion probability would have to exceed 1, it
    saturates instead, and the 1/(N p_c) weights turn the estimator
    SEVERELY biased (worse than the unweighted mean).  The constructor
    rejects such configurations; shrink ``frac`` or flatten ``probs``.

    Uniform probabilities are detected statically: the tilt is then the
    identity (``z = u``) and all weights are exactly 1.0, so the trajectory
    is pinned BITWISE to ``UniformParticipation`` with the same
    (frac, seed) -- masked_mean's numerator multiplies by exactly 1.0 and
    its static denominator equals the float cohort size the 0/1 path sums.
    """
    num_clients: int
    probs: tuple[float, ...]    # per-client sampling distribution (sums to 1)
    frac: float = 0.25
    seed: int = 0

    def __post_init__(self):
        assert self.num_clients >= 1
        assert len(self.probs) == self.num_clients, \
            f"need {self.num_clients} probs, got {len(self.probs)}"
        assert all(p > 0.0 for p in self.probs), "probs must be positive"
        assert abs(sum(self.probs) - 1.0) < 1e-6, "probs must sum to 1"
        assert 0.0 < self.frac <= 1.0, f"frac {self.frac} not in (0, 1]"
        assert self.cohort_size >= 1, "policy must sample >=1 client"
        assert self.cohort_size * max(self.probs) <= 1.0 + 1e-9, (
            f"cohort {self.cohort_size} x max prob {max(self.probs)} > 1: "
            "the pi_c ~= m p_c inclusion approximation saturates and the "
            "1/(N p_c) reweighting becomes severely biased -- shrink frac "
            "or flatten probs")

    @property
    def cohort_size(self) -> int:
        return max(1, int(round(self.frac * self.num_clients)))

    @property
    def uniform(self) -> bool:
        """Statically-detected uniform distribution: identity tilt, unit
        weights (the bitwise pin to UniformParticipation)."""
        return len(set(self.probs)) == 1

    def variates(self, t: jax.Array) -> jax.Array:
        """The policy's per-client round-t uniforms (``round_variates``) --
        the same stream ``UniformParticipation`` with this seed draws."""
        return round_variates(self.num_clients, self.seed, t)

    def _np_rates(self) -> np.ndarray:
        return (self.num_clients
                * np.asarray(self.probs, np.float64)).astype(np.float32)

    def mask(self, t: jax.Array) -> dict:
        u = self.variates(t)
        if self.uniform:
            z = u                       # identity tilt: exact bitwise pin
            w = jnp.ones((self.num_clients,), jnp.float32)
        else:
            z = -jnp.log1p(-u) / jnp.asarray(self._np_rates())
            w = jnp.asarray((1.0 / self._np_rates().astype(np.float64))
                            .astype(np.float32))
        m = self.cohort_size
        order = jnp.argsort(z)
        sel = jnp.zeros((self.num_clients,), jnp.float32).at[
            order[:m]].set(1.0)
        return {"w": sel * w, "den": float(m), "n": m}


@dataclasses.dataclass(frozen=True)
class FixedCohort:
    """A static cohort: the same client subset reports every round."""
    num_clients: int
    clients: tuple[int, ...] = (0,)

    def __post_init__(self):
        assert len(self.clients) >= 1, "policy must sample >=1 client"
        assert all(0 <= c < self.num_clients for c in self.clients)

    @property
    def cohort_size(self) -> int:
        return len(set(self.clients))

    def mask(self, t: jax.Array) -> jax.Array:
        m = np.zeros((self.num_clients,), np.float32)
        m[list(self.clients)] = 1.0
        return jnp.asarray(m)


@dataclasses.dataclass(frozen=True)
class AvailabilityTrace:
    """Cyclic availability: round t's cohort is row ``t % P`` of a fixed
    (P, num_clients) 0/1 trace -- diurnal/charging-window availability at
    simulation scale.  ``round_robin`` builds the canonical cyclic split
    where client c is available iff ``c % groups == t % groups``."""
    trace: tuple[tuple[float, ...], ...]     # (P, N) rows of 0/1

    def __post_init__(self):
        assert len(self.trace) >= 1
        n = len(self.trace[0])
        assert all(len(row) == n for row in self.trace)
        assert all(sum(row) >= 1 for row in self.trace), \
            "every trace row must have >=1 available client"

    @classmethod
    def round_robin(cls, num_clients: int, groups: int) -> "AvailabilityTrace":
        assert 1 <= groups <= num_clients
        rows = tuple(tuple(1.0 if c % groups == g else 0.0
                           for c in range(num_clients))
                     for g in range(groups))
        return cls(trace=rows)

    @property
    def num_clients(self) -> int:
        return len(self.trace[0])

    @property
    def cohort_size(self) -> int:
        """Largest per-round cohort (upper bound for bits accounting)."""
        return int(max(sum(row) for row in self.trace))

    def mask(self, t: jax.Array) -> jax.Array:
        trace = jnp.asarray(self.trace, jnp.float32)
        return trace[jnp.mod(t, trace.shape[0])]


@dataclasses.dataclass(frozen=True)
class FullParticipation:
    """All N clients every round -- the paper's setting, as a policy.  Its
    all-ones mask routes through the masked aggregation path and is pinned
    bitwise-equal to passing no mask at all (tests/test_fed.py)."""
    num_clients: int

    def __post_init__(self):
        assert self.num_clients >= 1

    @property
    def cohort_size(self) -> int:
        return self.num_clients

    def mask(self, t: jax.Array) -> jax.Array:
        return jnp.ones((self.num_clients,), jnp.float32)
