"""Client participation + robustness subsystem: partial participation,
async staleness buffers, sampling policies, deterministic fault injection,
sketch-space payload sentinels, and the quantized payload codec
(``codec``: int8 / 1-bit stochastic rounding with sketch-space error
feedback and measured ``uplink_bits``) for the on-device scan driver
(DESIGN.md §7, §10, §13).
"""

from repro.fed.async_buffer import (AsyncConfig, arrival_weight,
                                    init_async_state, make_async_round)
from repro.fed.codec import (CodecConfig, encode_decode, init_codec_state,
                             measured_uplink_bits)
from repro.fed.faults import (BYZANTINE, DROP, INF, NAN, OK, FaultConfig,
                              FaultTable, corrupt_payload, fold_arrivals)
from repro.fed.robust import (SentinelConfig, carry_if_empty,
                              divergence_flag, guard_uplink, masked_median)
from repro.fed.participation import (AvailabilityTrace, FixedCohort,
                                     FullParticipation,
                                     ImportanceParticipation,
                                     UniformParticipation,
                                     check_policy_clients, is_weighted_mask,
                                     masked_mean, masked_mean_tree,
                                     round_variates)
