"""Client participation subsystem: partial participation, async staleness
buffers, and sampling policies for the on-device scan driver (DESIGN.md §7).
"""

from repro.fed.async_buffer import (AsyncConfig, arrival_weight,
                                    init_async_state, make_async_round)
from repro.fed.participation import (AvailabilityTrace, FixedCohort,
                                     FullParticipation,
                                     ImportanceParticipation,
                                     UniformParticipation,
                                     check_policy_clients, is_weighted_mask,
                                     masked_mean, masked_mean_tree,
                                     round_variates)
