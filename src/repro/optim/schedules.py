"""Learning-rate schedules (paper Appendix D uses cosine on the server;
Theorem 3.2/B.3 analyze constant and 1/sqrt(t) decays)."""

from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> multiplier


def constant() -> Schedule:
    return lambda t: jnp.ones_like(t, jnp.float32)


def inv_sqrt(t0: float = 1.0) -> Schedule:
    """eta_t = 1 / sqrt(t + t0): the decay analyzed in Theorem B.3."""
    return lambda t: 1.0 / jnp.sqrt(t.astype(jnp.float32) + t0)


def cosine(total_steps: int, min_frac: float = 1e-3,
           warmup: int = 0) -> Schedule:
    """Cosine decay to min_frac with optional linear warmup (paper App. D)."""
    def fn(t):
        t = t.astype(jnp.float32)
        warm = jnp.minimum(t / max(warmup, 1), 1.0) if warmup else 1.0
        frac = jnp.clip((t - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * frac))
        return warm * cos
    return fn


def sketch_size_schedule(base_ratio: float, total_steps: int,
                         final_frac: float = 1.0) -> Callable[[int], float]:
    """Beyond-paper: anneal the sketch ratio over rounds (DESIGN §7.2).
    Returns a python-level schedule (sketch size is a static shape, so it can
    only change at jit boundaries -- the trainer re-jits per phase)."""
    def fn(step: int) -> float:
        frac = min(max(step / max(total_steps, 1), 0.0), 1.0)
        return base_ratio * (1.0 + (final_frac - 1.0) * frac)
    return fn
