from repro.optim.schedules import constant, cosine, inv_sqrt, sketch_size_schedule
