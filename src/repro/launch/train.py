"""Distributed SAFL training / serving steps for the production mesh.

The FL topology maps onto the mesh (DESIGN §3): one client group per
(pod, data) index; the *sketched* uplink is a psum of b-dim vectors executed
inside a shard_map (so sketching is shard-local along the model axis -- no
all-gather of the d-dim delta ever happens).  The FedOpt baseline step
transmits raw deltas (an O(d) all-reduce) for roofline comparison.

Two drivers share one round core (DESIGN §8): the per-round jitted step
(``make_safl_train_step``; one host dispatch per round) and the scanned
multi-round driver (``make_safl_scan_fn`` / ``run_mesh_scan``; R rounds as
one ``lax.scan`` OUTSIDE the shard_map with donated
``(params, opt_state, data_state, key)`` carries, device-side sharded batch
sampling via ``mesh_sampler``, and chunked on-device loss history).  Both
are bit-identical per round (tests/test_mesh_scan.py).

Run as a module for a real (CPU-scale) training run:
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.adaptive import AdaConfig, apply_update, init_opt_state
from repro.core.packed import (PackingPlan, derive_round_params, desk_flat,
                               make_sharded_packing_plan, pack_tree, sk_flat,
                               unpack_tree)
from repro.core.safl import SAFLConfig, client_delta
from repro.core.sketch import (SKETCH_CHUNK_NUMEL, SketchConfig, desk_leaf,
                               desk_leaf_stacked, sk_leaf, sk_leaf_stacked)
from repro.models.config import ModelConfig
from repro.models.model import decode_step, forward, loss_fn, param_shapes
from repro.models.sharding import param_pspecs

try:  # jax>=0.6 moved shard_map to the top level (axis_names/check_vma API)
    _shard_map_impl = jax.shard_map
    _NEW_SHARD_MAP = True
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map_impl  # type: ignore
    _NEW_SHARD_MAP = False


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=False):
    """Version shim: call sites use the new-jax kwargs; on jax 0.4.x we
    translate axis_names (manual axes) to the old ``auto`` complement and
    check_vma to check_rep."""
    if _NEW_SHARD_MAP:
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, **kw)
    kw = {"check_rep": check_vma}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map_impl(f, mesh, in_specs, out_specs, **kw)

Pytree = Any


def data_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def client_axes_of(mesh, topology: str) -> tuple[str, ...]:
    """Mesh axes that enumerate FL clients.

    cross_device: every (pod, data) index is a client (weights replicated
    over data, tensor-parallel over model).  cross_device_dp: same clients,
    but the client's OWN batch is data-parallel over the model axis with
    fully replicated weights (beyond-paper §Perf: trades per-layer TP
    activation collectives for one grad all-reduce -- the right regime for
    <=3B models).  cross_silo: each pod is one client (weights FSDP-sharded
    within the pod) -- the mapping for 100B+ configs."""
    if topology == "cross_silo":
        return tuple(a for a in ("pod",) if a in mesh.axis_names)
    return data_axes_of(mesh)


def num_clients_of(mesh, topology: str) -> int:
    axes = client_axes_of(mesh, topology)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# shard-local sketch -> b-dim psum -> desk  (the compressed uplink)
# ---------------------------------------------------------------------------

_SKETCH_CHUNK_NUMEL = SKETCH_CHUNK_NUMEL   # back-compat alias


def _sketch_avg_desk_local(skcfg: SketchConfig, client_axes, deltas, key):
    """Per-leaf REFERENCE path, PER DEVICE inside shard_map.  deltas leaves:
    (G_loc, *local_shard).  Every cross-client collective in SAFL is the
    pmean below -- b floats per tensor, not d.

    Leaves whose local shard exceeds SKETCH_CHUNK_NUMEL are sketched per
    slice of their leading (layer-stack) axis via lax.map: this bounds the
    hash/sign temporaries to one layer's worth and realizes the layer-wise
    sketching the paper's conclusion proposes.

    This is the ``plan=None`` fallback; the production route is the packed
    plan path below (same per-leaf fold_in chain, no per-round Python tree
    traversal), pinned bitwise equal by tests/test_mesh_scan.py."""
    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    out = []
    for i, leaf in enumerate(leaves):
        lk = jax.random.fold_in(key, i)
        lshape = leaf.shape[1:]                     # drop local client dim
        numel = 1
        for d in lshape:
            numel *= d
        n0 = lshape[0] if lshape else 1
        if numel > SKETCH_CHUNK_NUMEL and len(lshape) >= 2 and n0 > 1:
            vs = leaf.reshape(n0, numel // n0).astype(jnp.float32)
            s = sk_leaf_stacked(skcfg, lk, vs)                # (n0, b_sub)
            if client_axes:
                s = jax.lax.pmean(s, client_axes)  # <-- compressed uplink
            u = desk_leaf_stacked(skcfg, lk, s, numel // n0)
            out.append(u.reshape(leaf.shape))
            continue
        v = leaf.reshape(-1).astype(jnp.float32)
        s = sk_leaf(skcfg, lk, v)
        if client_axes:
            s = jax.lax.pmean(s, client_axes)      # <-- compressed uplink
        u = desk_leaf(skcfg, lk, s, v.shape[0])
        out.append(u.reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def _sketch_avg_desk_local_packed(plan: PackingPlan, client_axes, deltas,
                                  key):
    """Plan-routed shard-local sketch, PER DEVICE inside shard_map.

    The static layout (``plan``, built once OUTSIDE the trace from the
    shard-local leaf shapes) replaces the per-leaf Python loop: the round's
    operator is derived ONCE (shared by sk and desk, per-leaf fold_in tags
    identical to the reference path), each local client row is packed into
    one contiguous buffer and compressed in one fused pass, and the pmean
    moves ONE (G_loc, b_total) payload.  Being trace-free state -- only the
    round key is traced -- this is what lets the multi-round scan carry the
    sketch path with zero per-round host work (DESIGN §8)."""
    rp = derive_round_params(plan, key)
    flat = jax.vmap(lambda t: pack_tree(plan, t))(deltas)   # (G_loc, d_loc)
    s = jax.vmap(lambda f: sk_flat(plan, rp, f))(flat)      # (G_loc, b_tot)
    if client_axes:
        s = jax.lax.pmean(s, client_axes)          # <-- compressed uplink
    u = jax.vmap(lambda p: desk_flat(plan, rp, p))(s)
    return jax.vmap(lambda f: unpack_tree(plan, f, cast=False))(u)


def sharded_sketch_avg_desk(mesh, skcfg: SketchConfig, pspecs, deltas, key,
                            topology: str = "cross_device", plan=None):
    """Sketch each client delta (shard-local), pmean over client axes,
    desketch.

    deltas leaves: (G, *param_shape), G sharded over the client axes; param
    dims sharded per ``pspecs``.  Returns the update tree with param
    sharding.  ``plan`` (optional) is the shard-local ``PackingPlan`` from
    ``core.packed.make_sharded_packing_plan``: when given, leaf sketching
    runs through the fused packed engine (one dispatch, operator derived
    once); ``plan=None`` keeps the per-leaf reference loop.  Both produce
    identical values for shards below the layer-chunk threshold
    (tests/test_mesh_scan.py pins this bitwise)."""
    client_axes = client_axes_of(mesh, topology)
    lead = client_axes if client_axes else None
    in_specs = jax.tree.map(
        lambda ps: P(*((lead,) + tuple(ps))), pspecs,
        is_leaf=lambda x: isinstance(x, P))
    out_specs = pspecs
    if plan is not None:
        fn = functools.partial(_sketch_avg_desk_local_packed, plan,
                               client_axes)
    else:
        fn = functools.partial(_sketch_avg_desk_local, skcfg, client_axes)

    def local(d, k):
        upd = fn(d, k)
        # fold the local client axis (size 1 when G == #client groups;
        # mean over it otherwise)
        return jax.tree.map(lambda u: u.mean(axis=0), upd)

    return shard_map(local, mesh=mesh,
                     in_specs=(in_specs, P()), out_specs=out_specs,
                     check_vma=False)(deltas, key)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def client_deltas_sharded(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                          topology: str, params, batch, eta):
    """Per-client local training, manual over the client axes and AUTO/GSPMD
    over the model (+FSDP) axes: each client group runs K local SGD steps on
    its own replica with zero cross-client communication.  Returns
    (deltas (G, *param), losses (G,))."""
    from repro.models.sharding import manual_axes
    loss = lambda p, b: loss_fn(model_cfg, p, b)
    caxes = client_axes_of(mesh, topology)

    # in dp mode all model-axis hints are disabled so GSPMD freely
    # propagates the batch-over-model sharding
    haxes = caxes + (("model",) if topology == "cross_device_dp" else ())

    def body(p, b_local):
        with manual_axes(haxes):
            mb = jax.tree.map(lambda x: x[0], b_local)      # drop local G=1
            if topology == "cross_device_dp":
                mb = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, P(None, "model") if x.ndim >= 2 else P()), mb)
            delta, l = client_delta(safl_cfg, loss, p, mb, eta)
        delta = jax.tree.map(lambda d: d[None], delta)
        return delta, l[None]

    if not caxes:                                            # 1 client total
        return body(params, batch)

    if topology == "cross_silo":
        # XLA's SPMD partitioner cannot handle partial-manual shard_map over
        # the pod axis of a 3-axis mesh (hard CHECK failure); the vmap
        # formulation partitions cleanly here because the client count (2
        # pods) matches the pod axis exactly and weights carry no pod axis.
        with manual_axes(()):
            def one(mb):
                return client_delta(safl_cfg, loss, params, mb, eta)
            deltas, losses = jax.vmap(one)(batch)
        return deltas, losses

    lead = P(caxes)
    b_specs = jax.tree.map(lambda x: lead, batch)
    d_specs = jax.tree.map(lambda x: lead, params)
    return shard_map(body, mesh=mesh,
                     in_specs=(P(), b_specs),
                     out_specs=(d_specs, lead),
                     axis_names=set(caxes), check_vma=False)(params, batch)


def _mesh_pspecs(model_cfg: ModelConfig, topology: str):
    abstract = jax.eval_shape(
        lambda: jax.tree.map(lambda s: jnp.zeros(s, model_cfg.dtype),
                             param_shapes(model_cfg),
                             is_leaf=lambda x: isinstance(x, tuple)))
    if topology == "cross_device_dp":
        pspecs = jax.tree.map(lambda p: P(*((None,) * len(p))),
                              param_pspecs(abstract),
                              is_leaf=lambda x: isinstance(x, P))
    else:
        pspecs = param_pspecs(abstract, fsdp=(topology == "cross_silo"))
    return abstract, pspecs


def _make_round_core(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                     topology: str = "cross_device"):
    """The typed-key SAFL mesh round:
    ``core(params, opt_state, batch, round_key) -> (params, opt_state,
    loss)``.

    The shard-local ``PackingPlan`` is built HERE, once, outside any trace
    (``core.packed.make_sharded_packing_plan``), so only the round operator
    (``derive_round_params``) depends on the round key -- the sketch path is
    trace-free state a multi-round ``lax.scan`` can thread through its
    carry.  Models with a local shard above ``SKETCH_CHUNK_NUMEL`` keep the
    per-leaf reference path instead (``plan=None``): its layer-chunked
    lax.map bounds the operator temporaries to one layer slice, which the
    whole-leaf packed route would not.  ``make_safl_train_step`` wraps this
    with the key_data calling convention; ``make_safl_scan_fn`` scans it."""
    from repro.core.packed import shard_local_abstract
    abstract, pspecs = _mesh_pspecs(model_cfg, topology)
    plan = None
    if safl_cfg.sketch.kind != "none":
        local_abs = shard_local_abstract(abstract, pspecs, dict(mesh.shape))
        if all(int(np.prod(l.shape)) <= SKETCH_CHUNK_NUMEL
               for l in jax.tree.leaves(local_abs)):
            plan = make_sharded_packing_plan(safl_cfg.sketch, abstract,
                                             pspecs, dict(mesh.shape))

    def core(params, opt_state, batch, key):
        eta = jnp.asarray(safl_cfg.client_lr, jnp.float32)
        deltas, losses = client_deltas_sharded(
            model_cfg, safl_cfg, mesh, topology, params, batch, eta)
        if safl_cfg.sketch.kind == "none":
            # FedOpt baseline: raw-delta mean = O(d) all-reduce over clients
            update = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas)
        else:
            update = sharded_sketch_avg_desk(
                mesh, safl_cfg.sketch, pspecs, deltas, key, topology,
                plan=plan)
        params, opt_state = apply_update(
            safl_cfg.server, opt_state, params, update)
        return params, opt_state, jnp.mean(losses)

    return core, pspecs


def make_safl_train_step(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                         topology: str = "cross_device"):
    """SAFL round on the mesh.  batch leaves: (G, K, mb, ...) with G = number
    of FL clients (data-parallel groups or pods, per ``topology``)."""
    core, pspecs = _make_round_core(model_cfg, safl_cfg, mesh, topology)

    def step(params, opt_state, batch, key_data):
        return core(params, opt_state, batch,
                    jax.random.wrap_key_data(key_data))

    return step, pspecs


def _fedopt_cfg(safl_cfg: SAFLConfig) -> SAFLConfig:
    return SAFLConfig(sketch=SketchConfig(kind="none"),
                      server=safl_cfg.server,
                      client_lr=safl_cfg.client_lr,
                      local_steps=safl_cfg.local_steps,
                      remat_local=safl_cfg.remat_local)


def make_fedopt_train_step(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                           topology: str = "cross_device"):
    """Uncompressed FedOPT baseline: raw-delta mean = O(d) all-reduce."""
    return make_safl_train_step(model_cfg, _fedopt_cfg(safl_cfg), mesh,
                                topology)


# ---------------------------------------------------------------------------
# multi-pod scanned mesh driver: scan OUTSIDE the shard_map round (DESIGN §8)
# ---------------------------------------------------------------------------

def mesh_sampler(mesh, sampler, topology: str = "cross_device"):
    """Wrap a device sampler (``init_state()/sample(state, t)``) so its
    ``(G, K, mb, ...)`` batches land sharded on the mesh per
    ``batch_pspecs`` -- G over the client axes, mb over ``data`` in
    cross_silo.  The constraint is pure layout (tokens bitwise unchanged),
    so mesh and single-host trajectories stay comparable."""
    from repro.data.device import ShardedSampler
    st = jax.eval_shape(sampler.init_state)
    babs = jax.eval_shape(sampler.sample, st,
                          jax.ShapeDtypeStruct((), jnp.int32))[1]
    shardings = to_shardings(mesh, batch_pspecs(babs, mesh, topology))
    return ShardedSampler(sampler, shardings)


def make_safl_scan_fn(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                      topology: str = "cross_device", *, sampler,
                      num_rounds: int, donate: bool = True):
    """Jit ``num_rounds`` SAFL mesh rounds as ONE ``lax.scan`` dispatch.

    The scan sits OUTSIDE the shard_map round: each scanned step draws its
    batch on device (``sampler.sample(data_state, t)``, sharded via
    ``mesh_sampler``), derives the round key as ``fold_in(key, t)`` inside
    the scan body, and runs the same round core the per-round jitted step
    uses -- so scanned and per-round mesh trajectories are bit-identical
    (tests/test_mesh_scan.py).  The ``(params, opt_state, data_state, key)``
    carry is DONATED: large models update in place across chunks, and the
    host pays one dispatch + one metric fetch per chunk instead of per
    round.

    Signature of the returned fn:
        ``(params, opt_state, data_state, key_data, t0) ->
           (params, opt_state, data_state, key_data, hist)``
    ``t0`` is a traced scalar so successive chunks of one length share one
    executable; ``hist["loss"]`` is the chunk's on-device loss history.
    Returns ``(chunk_fn, pspecs)``.
    """
    core, pspecs = _make_round_core(model_cfg, safl_cfg, mesh, topology)

    def chunk(params, opt_state, data_state, key_data, t0):
        def body(carry, t):
            params, opt_state, dstate, kd = carry
            dstate, batch = sampler.sample(dstate, t)
            rk = jax.random.fold_in(jax.random.wrap_key_data(kd), t)
            params, opt_state, loss = core(params, opt_state, batch, rk)
            return (params, opt_state, dstate, kd), {"loss": loss}

        (params, opt_state, data_state, key_data), hist = jax.lax.scan(
            body, (params, opt_state, data_state, key_data),
            t0 + jnp.arange(num_rounds, dtype=jnp.int32))
        return params, opt_state, data_state, key_data, hist

    return (jax.jit(chunk, donate_argnums=(0, 1, 2, 3) if donate else ()),
            pspecs)


def make_fedopt_scan_fn(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                        topology: str = "cross_device", *, sampler,
                        num_rounds: int, donate: bool = True):
    """Scanned uncompressed FedOPT mesh rounds (``sketch.kind == "none"``:
    the raw-delta O(d) all-reduce inside the same scan layout)."""
    return make_safl_scan_fn(model_cfg, _fedopt_cfg(safl_cfg), mesh,
                             topology, sampler=sampler,
                             num_rounds=num_rounds, donate=donate)


def run_mesh_scan(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh, sampler,
                  params, opt_state, *, rounds: int, key,
                  topology: str = "cross_device", chunk_size: int = 0,
                  start_round: int = 0, donate: bool = True, on_chunk=None):
    """Run ``rounds`` mesh rounds in scanned chunks (the multi-pod analogue
    of ``launch.driver.run_scan``).

    ``chunk_size`` bounds rounds per dispatch (0 = all in one); metrics
    cross to the host once per chunk and ``on_chunk(t_done, params,
    opt_state, chunk_hist)`` runs between chunks.  ``start_round`` resumes a
    ``(t, key)`` checkpoint cursor mid-trajectory (every per-round stream is
    a pure function of the absolute round index under ``key``).  Returns
    ``(params, opt_state, history)`` with host-side
    ``(rounds - start_round,)`` arrays."""
    chunk_size = int(chunk_size) or int(rounds)
    data_state = sampler.init_state()
    # host copy of the (invariant) base key: the donated key carry comes
    # back as a pass-through output of its own donated buffer, so each chunk
    # gets a fresh device copy instead of rethreading a deleted array
    kd_host = np.asarray(jax.random.key_data(key))
    compiled: dict[int, Callable] = {}
    hists = []
    t = int(start_round)
    while t < rounds:
        n = min(chunk_size, rounds - t)
        if n not in compiled:   # tail chunk of a different length re-jits
            compiled[n], _ = make_safl_scan_fn(
                model_cfg, safl_cfg, mesh, topology, sampler=sampler,
                num_rounds=n, donate=donate)
        params, opt_state, data_state, _, hist = compiled[n](
            params, opt_state, data_state, jnp.asarray(kd_host),
            jnp.asarray(t, jnp.int32))
        hist = jax.tree.map(np.asarray, hist)      # ONE fetch per chunk
        hists.append(hist)
        t += n
        if on_chunk is not None:
            on_chunk(t, params, opt_state, hist)
    if not hists:       # resumed at start_round == rounds: nothing to run
        return params, opt_state, {}
    history = jax.tree.map(lambda *xs: np.concatenate(xs), *hists)
    return params, opt_state, history


def run_mesh_host_loop(step, sampler, params, opt_state, *, rounds: int, key,
                       start_round: int = 0, donate: bool = True):
    """One-jitted-dispatch-per-round mesh reference with the scanned
    driver's EXACT key/batch sequence: round t consumes
    ``key_data(fold_in(key, t))`` and ``sampler.sample(state, t)``.
    ``step`` is the per-round fn from ``make_safl_train_step`` /
    ``make_fedopt_train_step``.  benchmarks/run.py times this against
    ``run_mesh_scan`` (mesh/<algo> vs mesh/<algo>_scan); the trajectories
    agree bitwise."""
    data_state = sampler.init_state()
    sample = jax.jit(sampler.sample)
    jstep = jax.jit(step, donate_argnums=(0, 1) if donate else ())
    losses = []
    for t in range(int(start_round), rounds):
        data_state, batch = sample(data_state, jnp.asarray(t, jnp.int32))
        kd = jax.random.key_data(jax.random.fold_in(key, t))
        params, opt_state, loss = jstep(params, opt_state, batch, kd)
        losses.append(np.asarray(loss))            # blocks every round
    return params, opt_state, {"loss": np.stack(losses)}


def make_prefill_step(model_cfg: ModelConfig):
    def step(params, batch):
        h, _ = forward(model_cfg, params, batch, remat=False)
        head = (params["embed"].T if model_cfg.tie_embeddings
                else params["lm_head"])
        return h[:, -1] @ head                      # (B, V) last-token logits
    return step


def make_serve_step(model_cfg: ModelConfig):
    def step(params, cache, tokens, pos):
        return decode_step(model_cfg, params, cache, tokens, pos)
    return step


# ---------------------------------------------------------------------------
# sharding spec helpers for jit in_shardings
# ---------------------------------------------------------------------------

def batch_pspecs(batch_tree, mesh, topology: str = "cross_device") -> Pytree:
    """Train-batch specs: (G, K, mb, ...).  cross_device shards G over
    (pod, data); cross_silo shards G over pod and mb over data."""
    caxes = client_axes_of(mesh, topology)
    lead = caxes if caxes else None
    if topology == "cross_silo":
        inner = "data" if "data" in mesh.axis_names else None
        return jax.tree.map(
            lambda x: P(*((lead, None, inner) + (None,) * (x.ndim - 3))),
            batch_tree)
    if topology == "cross_device_dp":
        return jax.tree.map(
            lambda x: P(*((lead, None, "model") + (None,) * (x.ndim - 3))),
            batch_tree)
    return jax.tree.map(
        lambda x: P(*((lead,) + (None,) * (x.ndim - 1))), batch_tree)


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def infer_batch_pspecs(batch_tree, data_axes, mesh=None) -> Pytree:
    """Inference batch: leading batch dim over (pod, data); left replicated
    when the batch does not divide the axes (e.g. long_500k with B=1)."""
    def spec(x):
        axes = data_axes
        if mesh is not None and x.shape[0] % _axes_size(mesh, data_axes):
            axes = None
        return P(*((axes,) + (None,) * (x.ndim - 1)))
    return jax.tree.map(spec, batch_tree)


def cache_pspecs(cache_tree, data_axes, mesh=None) -> Pytree:
    """KV caches are sequence-sharded over the model axis (flash-decoding
    style partial softmax via GSPMD); SSM state shards d_inner.  The batch
    dim falls back to replicated when it does not divide the data axes."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = []
    for path, leaf in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        nd = leaf.ndim
        baxes = data_axes
        if mesh is not None and leaf.shape[1] % _axes_size(mesh, data_axes):
            baxes = None
        if name in ("k", "v", "xk", "xv"):       # (nb, B, S, Hk, hd)
            sp = (None, baxes, "model", None, None)
        elif name in ("ckv", "kpe"):             # (nb, B, S, r)
            sp = (None, baxes, "model", None)
        elif name == "h":                        # (nb, B, di, ds)
            sp = (None, baxes, "model", None)
        elif name == "conv":                     # (nb, B, kw-1, di)
            sp = (None, baxes, None, "model")
        else:
            sp = (None,) * nd
        if mesh is not None:
            # drop any axis a dim cannot divide (e.g. whisper's 1500-frame
            # cross cache on a 16-way model axis)
            fixed = []
            for dim, e in zip(leaf.shape, sp[:nd]):
                if e is None:
                    fixed.append(None)
                    continue
                axes = e if isinstance(e, tuple) else (e,)
                fixed.append(e if dim % _axes_size(mesh, axes) == 0 else None)
            sp = tuple(fixed)
        specs.append(P(*sp[:nd]))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_pspecs(server: AdaConfig, pspecs) -> dict:
    out = {"step": P()}
    for k in ("m", "v", "vhat"):
        if (server.name in ("amsgrad", "adam", "sgdm") and k == "m") or \
           (server.name in ("amsgrad", "adam", "adagrad") and k == "v") or \
           (server.name == "amsgrad" and k == "vhat"):
            out[k] = pspecs
    return out


def to_shardings(mesh, pspec_tree):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# runnable single-host trainer (examples / integration tests use this)
# ---------------------------------------------------------------------------

def train_loop(model_cfg: ModelConfig, safl_cfg: SAFLConfig, data,
               rounds: int, *, batch_per_client: int = 8, log_every: int = 10,
               seed: int = 0, scan: bool = True, chunk_size: int = 0):
    """CPU-scale SAFL training on real (synthetic-dataset) batches.

    When ``data`` supports device-side sampling (``device_sampler``) the
    whole run executes as scanned on-device chunks with donated carries
    (launch/driver.py, DESIGN.md §6); metrics come back once per chunk.
    Other datasets fall back to the host-driven loop (still with donated
    params/opt buffers, so no per-round copy)."""
    from repro.core.packed import make_packing_plan
    from repro.core.safl import init_safl, safl_round
    key = jax.random.key(seed)
    from repro.models.model import init_params
    params = init_params(model_cfg, key)
    opt = init_safl(safl_cfg, params)
    loss = lambda p, b: loss_fn(model_cfg, p, b)
    # static sketch layout built ONCE, outside any trace
    plan = make_packing_plan(safl_cfg.sketch, params)
    round_fn = functools.partial(safl_round, safl_cfg, loss, plan=plan)

    if scan and hasattr(data, "device_sampler"):
        from repro.launch.driver import run_scan
        sampler = data.device_sampler(batch_per_client, safl_cfg.local_steps)

        def on_chunk(t_done, _params, _opt, hist):
            if log_every:
                print(f"round {t_done - 1:4d}  loss {hist['loss'][-1]:.4f}")

        params, opt, hist = run_scan(
            round_fn, sampler, params, opt, rounds=rounds, key=key,
            chunk_size=chunk_size or (log_every or rounds),
            on_chunk=on_chunk)
        return params, opt, [float(x) for x in hist["loss"]]

    round_jit = jax.jit(round_fn, donate_argnums=(0, 1))
    history = []
    for t in range(rounds):
        batch = data.round_batch(batch_per_client, safl_cfg.local_steps, t)
        params, opt, m = round_jit(params, opt, batch, jax.random.fold_in(key, t))
        history.append(float(m["loss"]))
        if log_every and (t % log_every == 0 or t == rounds - 1):
            print(f"round {t:4d}  loss {history[-1]:.4f}")
    return params, opt, history


def _main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--sketch", default="countsketch")
    ap.add_argument("--ratio", type=float, default=0.1)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data import BigramLMData, LMDataConfig
    cfg = get_config(args.arch, smoke=args.smoke)
    safl = SAFLConfig(
        sketch=SketchConfig(kind=args.sketch, ratio=args.ratio),
        server=AdaConfig(name="amsgrad", lr=0.003),
        client_lr=0.05, local_steps=args.local_steps)
    data = BigramLMData(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, num_clients=args.clients))
    train_loop(cfg, safl, data, args.rounds)


if __name__ == "__main__":
    _main()


def flat_tp_pspecs(pspecs, params_abs=None) -> Pytree:
    """Beyond-paper serving layout: fold the data axis into the model axis
    (256-way pure TP), sharding every weight's CONTRACTING (input) dim.

    v2 after a refuted iteration (EXPERIMENTS §Perf H3): sharding output/head
    dims conflicts with the sequence-sharded KV cache and makes GSPMD
    all-gather the cache (1.8 TB/step observed).  Contracting-dim sharding
    keeps weights fully resident AND the cache sequence-sharded; every
    matmul just all-reduces its (tiny, batch x features) decode activation.
    MoE experts stay expert-sharded (resident) with token all-to-all."""
    _W = {"wq", "wk", "wv", "wo", "wi", "wg", "w_dq", "w_uq", "w_dkv",
          "w_kr", "w_uk", "w_uv", "lm_head", "mtp_head", "router",
          "x_proj", "dt_proj", "out_proj", "wx", "wz"}

    def conv(path, p):
        name = str(getattr(path[-1], "key", path[-1]))
        parent = str(getattr(path[-2], "key", path[-2])) if len(path) > 1 \
            else ""
        nd = len(p)
        tp = ("data", "model")
        if name in ("wi", "wg", "wo") and parent in ("moe",) and nd >= 3:
            # stacked experts (nb, E, in, out): shard E (resident experts)
            lead = nd - 3
            return P(*((None,) * lead + (tp, None, None)))
        if name == "embed":
            return P(tp, None)
        if name in _W and nd >= 2:
            # shard the contracting dim (second-to-last) -> output psum
            return P(*((None,) * (nd - 2) + (tp, None)))
        return P(*((None,) * nd))
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    return jax.tree_util.tree_unflatten(
        treedef, [conv(path, p) for path, p in flat])


def flat_tp_cache_pspecs(cache_tree, mesh=None) -> Pytree:
    """Cache layout for flat-TP serving: sequence dim over (data, model),
    batch replicated."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = []
    tp = ("data", "model")
    for path, leaf in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        nd = leaf.ndim
        if name in ("k", "v", "xk", "xv"):
            sp = (None, None, tp, None, None)
        elif name in ("ckv", "kpe"):
            sp = (None, None, tp, None)
        elif name == "h":
            sp = (None, None, tp, None)
        elif name == "conv":
            sp = (None, None, None, tp)
        else:
            sp = (None,) * nd
        if mesh is not None:
            fixed = []
            for dim, e in zip(leaf.shape, sp[:nd]):
                if e is None:
                    fixed.append(None)
                    continue
                axes = e if isinstance(e, tuple) else (e,)
                fixed.append(e if dim % _axes_size(mesh, axes) == 0 else None)
            sp = tuple(fixed)
        specs.append(P(*sp[:nd]))
    return jax.tree_util.tree_unflatten(treedef, specs)
