"""Distributed SAFL training / serving steps for the production mesh.

The FL topology maps onto the mesh (DESIGN §3): one client group per
(pod, data) index; the *sketched* uplink is a psum of b-dim vectors executed
inside a shard_map (so sketching is shard-local along the model axis -- no
all-gather of the d-dim delta ever happens).  The FedOpt baseline step
transmits raw deltas (an O(d) all-reduce) for roofline comparison.

Run as a module for a real (CPU-scale) training run:
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.adaptive import AdaConfig, apply_update, init_opt_state
from repro.core.safl import SAFLConfig, client_delta
from repro.core.sketch import SketchConfig, desk_leaf, sk_leaf
from repro.models.config import ModelConfig
from repro.models.model import decode_step, forward, loss_fn, param_shapes
from repro.models.sharding import param_pspecs

try:  # jax>=0.6 moved shard_map to the top level (axis_names/check_vma API)
    _shard_map_impl = jax.shard_map
    _NEW_SHARD_MAP = True
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map_impl  # type: ignore
    _NEW_SHARD_MAP = False


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=False):
    """Version shim: call sites use the new-jax kwargs; on jax 0.4.x we
    translate axis_names (manual axes) to the old ``auto`` complement and
    check_vma to check_rep."""
    if _NEW_SHARD_MAP:
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, **kw)
    kw = {"check_rep": check_vma}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map_impl(f, mesh, in_specs, out_specs, **kw)

Pytree = Any


def data_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def client_axes_of(mesh, topology: str) -> tuple[str, ...]:
    """Mesh axes that enumerate FL clients.

    cross_device: every (pod, data) index is a client (weights replicated
    over data, tensor-parallel over model).  cross_device_dp: same clients,
    but the client's OWN batch is data-parallel over the model axis with
    fully replicated weights (beyond-paper §Perf: trades per-layer TP
    activation collectives for one grad all-reduce -- the right regime for
    <=3B models).  cross_silo: each pod is one client (weights FSDP-sharded
    within the pod) -- the mapping for 100B+ configs."""
    if topology == "cross_silo":
        return tuple(a for a in ("pod",) if a in mesh.axis_names)
    return data_axes_of(mesh)


def num_clients_of(mesh, topology: str) -> int:
    axes = client_axes_of(mesh, topology)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# shard-local sketch -> b-dim psum -> desk  (the compressed uplink)
# ---------------------------------------------------------------------------

_SKETCH_CHUNK_NUMEL = 1 << 24   # leaves above this sketch per layer-slice


def _sketch_avg_desk_local(skcfg: SketchConfig, client_axes, deltas, key):
    """Runs PER DEVICE inside shard_map.  deltas leaves: (G_loc, *local_shard).
    Every cross-client collective in SAFL is the pmean below -- b floats per
    tensor, not d.

    Leaves whose local shard exceeds _SKETCH_CHUNK_NUMEL are sketched per
    slice of their leading (layer-stack) axis via lax.map: this bounds the
    hash/sign temporaries to one layer's worth and realizes the layer-wise
    sketching the paper's conclusion proposes."""
    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    out = []
    for i, leaf in enumerate(leaves):
        lk = jax.random.fold_in(key, i)
        lshape = leaf.shape[1:]                     # drop local client dim
        numel = 1
        for d in lshape:
            numel *= d
        n0 = lshape[0] if lshape else 1
        if numel > _SKETCH_CHUNK_NUMEL and len(lshape) >= 2 and n0 > 1:
            vs = leaf.reshape(n0, numel // n0).astype(jnp.float32)

            def sk_one(args):
                j, v = args
                return sk_leaf(skcfg, jax.random.fold_in(lk, j), v)

            s = jax.lax.map(sk_one, (jnp.arange(n0), vs))     # (n0, b_sub)
            if client_axes:
                s = jax.lax.pmean(s, client_axes)  # <-- compressed uplink

            def desk_one(args):
                j, sj = args
                return desk_leaf(skcfg, jax.random.fold_in(lk, j), sj,
                                 numel // n0)

            u = jax.lax.map(desk_one, (jnp.arange(n0), s))
            out.append(u.reshape(leaf.shape))
            continue
        v = leaf.reshape(-1).astype(jnp.float32)
        s = sk_leaf(skcfg, lk, v)
        if client_axes:
            s = jax.lax.pmean(s, client_axes)      # <-- compressed uplink
        u = desk_leaf(skcfg, lk, s, v.shape[0])
        out.append(u.reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def sharded_sketch_avg_desk(mesh, skcfg: SketchConfig, pspecs, deltas, key,
                            topology: str = "cross_device"):
    """Sketch each client delta (shard-local), pmean over client axes,
    desketch.

    deltas leaves: (G, *param_shape), G sharded over the client axes; param
    dims sharded per ``pspecs``.  Returns the update tree with param
    sharding."""
    client_axes = client_axes_of(mesh, topology)
    lead = client_axes if client_axes else None
    in_specs = jax.tree.map(
        lambda ps: P(*((lead,) + tuple(ps))), pspecs,
        is_leaf=lambda x: isinstance(x, P))
    out_specs = pspecs
    fn = functools.partial(_sketch_avg_desk_local, skcfg, client_axes)

    def local(d, k):
        upd = fn(d, k)
        # fold the local client axis (size 1 when G == #client groups;
        # mean over it otherwise)
        return jax.tree.map(lambda u: u.mean(axis=0), upd)

    return shard_map(local, mesh=mesh,
                     in_specs=(in_specs, P()), out_specs=out_specs,
                     check_vma=False)(deltas, key)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def client_deltas_sharded(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                          topology: str, params, batch, eta):
    """Per-client local training, manual over the client axes and AUTO/GSPMD
    over the model (+FSDP) axes: each client group runs K local SGD steps on
    its own replica with zero cross-client communication.  Returns
    (deltas (G, *param), losses (G,))."""
    from repro.models.sharding import manual_axes
    loss = lambda p, b: loss_fn(model_cfg, p, b)
    caxes = client_axes_of(mesh, topology)

    # in dp mode all model-axis hints are disabled so GSPMD freely
    # propagates the batch-over-model sharding
    haxes = caxes + (("model",) if topology == "cross_device_dp" else ())

    def body(p, b_local):
        with manual_axes(haxes):
            mb = jax.tree.map(lambda x: x[0], b_local)      # drop local G=1
            if topology == "cross_device_dp":
                mb = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, P(None, "model") if x.ndim >= 2 else P()), mb)
            delta, l = client_delta(safl_cfg, loss, p, mb, eta)
        delta = jax.tree.map(lambda d: d[None], delta)
        return delta, l[None]

    if not caxes:                                            # 1 client total
        return body(params, batch)

    if topology == "cross_silo":
        # XLA's SPMD partitioner cannot handle partial-manual shard_map over
        # the pod axis of a 3-axis mesh (hard CHECK failure); the vmap
        # formulation partitions cleanly here because the client count (2
        # pods) matches the pod axis exactly and weights carry no pod axis.
        with manual_axes(()):
            def one(mb):
                return client_delta(safl_cfg, loss, params, mb, eta)
            deltas, losses = jax.vmap(one)(batch)
        return deltas, losses

    lead = P(caxes)
    b_specs = jax.tree.map(lambda x: lead, batch)
    d_specs = jax.tree.map(lambda x: lead, params)
    return shard_map(body, mesh=mesh,
                     in_specs=(P(), b_specs),
                     out_specs=(d_specs, lead),
                     axis_names=set(caxes), check_vma=False)(params, batch)


def make_safl_train_step(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                         topology: str = "cross_device"):
    """SAFL round on the mesh.  batch leaves: (G, K, mb, ...) with G = number
    of FL clients (data-parallel groups or pods, per ``topology``)."""
    abstract = jax.eval_shape(
        lambda: jax.tree.map(lambda s: jnp.zeros(s, model_cfg.dtype),
                             param_shapes(model_cfg),
                             is_leaf=lambda x: isinstance(x, tuple)))
    if topology == "cross_device_dp":
        pspecs = jax.tree.map(lambda p: P(*((None,) * len(p))),
                              param_pspecs(abstract),
                              is_leaf=lambda x: isinstance(x, P))
    else:
        pspecs = param_pspecs(abstract, fsdp=(topology == "cross_silo"))

    def step(params, opt_state, batch, key_data):
        key = jax.random.wrap_key_data(key_data)
        eta = jnp.asarray(safl_cfg.client_lr, jnp.float32)
        deltas, losses = client_deltas_sharded(
            model_cfg, safl_cfg, mesh, topology, params, batch, eta)
        if safl_cfg.sketch.kind == "none":
            # FedOpt baseline: raw-delta mean = O(d) all-reduce over clients
            update = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas)
        else:
            update = sharded_sketch_avg_desk(
                mesh, safl_cfg.sketch, pspecs, deltas, key, topology)
        params, opt_state = apply_update(
            safl_cfg.server, opt_state, params, update)
        return params, opt_state, jnp.mean(losses)

    return step, pspecs


def make_fedopt_train_step(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                           topology: str = "cross_device"):
    """Uncompressed FedOPT baseline: raw-delta mean = O(d) all-reduce."""
    cfg2 = SAFLConfig(sketch=SketchConfig(kind="none"),
                      server=safl_cfg.server,
                      client_lr=safl_cfg.client_lr,
                      local_steps=safl_cfg.local_steps,
                      remat_local=safl_cfg.remat_local)
    return make_safl_train_step(model_cfg, cfg2, mesh, topology)


def make_prefill_step(model_cfg: ModelConfig):
    def step(params, batch):
        h, _ = forward(model_cfg, params, batch, remat=False)
        head = (params["embed"].T if model_cfg.tie_embeddings
                else params["lm_head"])
        return h[:, -1] @ head                      # (B, V) last-token logits
    return step


def make_serve_step(model_cfg: ModelConfig):
    def step(params, cache, tokens, pos):
        return decode_step(model_cfg, params, cache, tokens, pos)
    return step


# ---------------------------------------------------------------------------
# sharding spec helpers for jit in_shardings
# ---------------------------------------------------------------------------

def batch_pspecs(batch_tree, mesh, topology: str = "cross_device") -> Pytree:
    """Train-batch specs: (G, K, mb, ...).  cross_device shards G over
    (pod, data); cross_silo shards G over pod and mb over data."""
    caxes = client_axes_of(mesh, topology)
    lead = caxes if caxes else None
    if topology == "cross_silo":
        inner = "data" if "data" in mesh.axis_names else None
        return jax.tree.map(
            lambda x: P(*((lead, None, inner) + (None,) * (x.ndim - 3))),
            batch_tree)
    if topology == "cross_device_dp":
        return jax.tree.map(
            lambda x: P(*((lead, None, "model") + (None,) * (x.ndim - 3))),
            batch_tree)
    return jax.tree.map(
        lambda x: P(*((lead,) + (None,) * (x.ndim - 1))), batch_tree)


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def infer_batch_pspecs(batch_tree, data_axes, mesh=None) -> Pytree:
    """Inference batch: leading batch dim over (pod, data); left replicated
    when the batch does not divide the axes (e.g. long_500k with B=1)."""
    def spec(x):
        axes = data_axes
        if mesh is not None and x.shape[0] % _axes_size(mesh, data_axes):
            axes = None
        return P(*((axes,) + (None,) * (x.ndim - 1)))
    return jax.tree.map(spec, batch_tree)


def cache_pspecs(cache_tree, data_axes, mesh=None) -> Pytree:
    """KV caches are sequence-sharded over the model axis (flash-decoding
    style partial softmax via GSPMD); SSM state shards d_inner.  The batch
    dim falls back to replicated when it does not divide the data axes."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = []
    for path, leaf in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        nd = leaf.ndim
        baxes = data_axes
        if mesh is not None and leaf.shape[1] % _axes_size(mesh, data_axes):
            baxes = None
        if name in ("k", "v", "xk", "xv"):       # (nb, B, S, Hk, hd)
            sp = (None, baxes, "model", None, None)
        elif name in ("ckv", "kpe"):             # (nb, B, S, r)
            sp = (None, baxes, "model", None)
        elif name == "h":                        # (nb, B, di, ds)
            sp = (None, baxes, "model", None)
        elif name == "conv":                     # (nb, B, kw-1, di)
            sp = (None, baxes, None, "model")
        else:
            sp = (None,) * nd
        if mesh is not None:
            # drop any axis a dim cannot divide (e.g. whisper's 1500-frame
            # cross cache on a 16-way model axis)
            fixed = []
            for dim, e in zip(leaf.shape, sp[:nd]):
                if e is None:
                    fixed.append(None)
                    continue
                axes = e if isinstance(e, tuple) else (e,)
                fixed.append(e if dim % _axes_size(mesh, axes) == 0 else None)
            sp = tuple(fixed)
        specs.append(P(*sp[:nd]))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_pspecs(server: AdaConfig, pspecs) -> dict:
    out = {"step": P()}
    for k in ("m", "v", "vhat"):
        if (server.name in ("amsgrad", "adam", "sgdm") and k == "m") or \
           (server.name in ("amsgrad", "adam", "adagrad") and k == "v") or \
           (server.name == "amsgrad" and k == "vhat"):
            out[k] = pspecs
    return out


def to_shardings(mesh, pspec_tree):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# runnable single-host trainer (examples / integration tests use this)
# ---------------------------------------------------------------------------

def train_loop(model_cfg: ModelConfig, safl_cfg: SAFLConfig, data,
               rounds: int, *, batch_per_client: int = 8, log_every: int = 10,
               seed: int = 0, scan: bool = True, chunk_size: int = 0):
    """CPU-scale SAFL training on real (synthetic-dataset) batches.

    When ``data`` supports device-side sampling (``device_sampler``) the
    whole run executes as scanned on-device chunks with donated carries
    (launch/driver.py, DESIGN.md §6); metrics come back once per chunk.
    Other datasets fall back to the host-driven loop (still with donated
    params/opt buffers, so no per-round copy)."""
    from repro.core.packed import make_packing_plan
    from repro.core.safl import init_safl, safl_round
    key = jax.random.key(seed)
    from repro.models.model import init_params
    params = init_params(model_cfg, key)
    opt = init_safl(safl_cfg, params)
    loss = lambda p, b: loss_fn(model_cfg, p, b)
    # static sketch layout built ONCE, outside any trace
    plan = make_packing_plan(safl_cfg.sketch, params)
    round_fn = functools.partial(safl_round, safl_cfg, loss, plan=plan)

    if scan and hasattr(data, "device_sampler"):
        from repro.launch.driver import run_scan
        sampler = data.device_sampler(batch_per_client, safl_cfg.local_steps)

        def on_chunk(t_done, _params, _opt, hist):
            if log_every:
                print(f"round {t_done - 1:4d}  loss {hist['loss'][-1]:.4f}")

        params, opt, hist = run_scan(
            round_fn, sampler, params, opt, rounds=rounds, key=key,
            chunk_size=chunk_size or (log_every or rounds),
            on_chunk=on_chunk)
        return params, opt, [float(x) for x in hist["loss"]]

    round_jit = jax.jit(round_fn, donate_argnums=(0, 1))
    history = []
    for t in range(rounds):
        batch = data.round_batch(batch_per_client, safl_cfg.local_steps, t)
        params, opt, m = round_jit(params, opt, batch, jax.random.fold_in(key, t))
        history.append(float(m["loss"]))
        if log_every and (t % log_every == 0 or t == rounds - 1):
            print(f"round {t:4d}  loss {history[-1]:.4f}")
    return params, opt, history


def _main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--sketch", default="countsketch")
    ap.add_argument("--ratio", type=float, default=0.1)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data import BigramLMData, LMDataConfig
    cfg = get_config(args.arch, smoke=args.smoke)
    safl = SAFLConfig(
        sketch=SketchConfig(kind=args.sketch, ratio=args.ratio),
        server=AdaConfig(name="amsgrad", lr=0.003),
        client_lr=0.05, local_steps=args.local_steps)
    data = BigramLMData(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, num_clients=args.clients))
    train_loop(cfg, safl, data, args.rounds)


if __name__ == "__main__":
    _main()


def flat_tp_pspecs(pspecs, params_abs=None) -> Pytree:
    """Beyond-paper serving layout: fold the data axis into the model axis
    (256-way pure TP), sharding every weight's CONTRACTING (input) dim.

    v2 after a refuted iteration (EXPERIMENTS §Perf H3): sharding output/head
    dims conflicts with the sequence-sharded KV cache and makes GSPMD
    all-gather the cache (1.8 TB/step observed).  Contracting-dim sharding
    keeps weights fully resident AND the cache sequence-sharded; every
    matmul just all-reduces its (tiny, batch x features) decode activation.
    MoE experts stay expert-sharded (resident) with token all-to-all."""
    _W = {"wq", "wk", "wv", "wo", "wi", "wg", "w_dq", "w_uq", "w_dkv",
          "w_kr", "w_uk", "w_uv", "lm_head", "mtp_head", "router",
          "x_proj", "dt_proj", "out_proj", "wx", "wz"}

    def conv(path, p):
        name = str(getattr(path[-1], "key", path[-1]))
        parent = str(getattr(path[-2], "key", path[-2])) if len(path) > 1 \
            else ""
        nd = len(p)
        tp = ("data", "model")
        if name in ("wi", "wg", "wo") and parent in ("moe",) and nd >= 3:
            # stacked experts (nb, E, in, out): shard E (resident experts)
            lead = nd - 3
            return P(*((None,) * lead + (tp, None, None)))
        if name == "embed":
            return P(tp, None)
        if name in _W and nd >= 2:
            # shard the contracting dim (second-to-last) -> output psum
            return P(*((None,) * (nd - 2) + (tp, None)))
        return P(*((None,) * nd))
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    return jax.tree_util.tree_unflatten(
        treedef, [conv(path, p) for path, p in flat])


def flat_tp_cache_pspecs(cache_tree, mesh=None) -> Pytree:
    """Cache layout for flat-TP serving: sequence dim over (data, model),
    batch replicated."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = []
    tp = ("data", "model")
    for path, leaf in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        nd = leaf.ndim
        if name in ("k", "v", "xk", "xv"):
            sp = (None, None, tp, None, None)
        elif name in ("ckv", "kpe"):
            sp = (None, None, tp, None)
        elif name == "h":
            sp = (None, None, tp, None)
        elif name == "conv":
            sp = (None, None, None, tp)
        else:
            sp = (None,) * nd
        if mesh is not None:
            fixed = []
            for dim, e in zip(leaf.shape, sp[:nd]):
                if e is None:
                    fixed.append(None)
                    continue
                axes = e if isinstance(e, tuple) else (e,)
                fixed.append(e if dim % _axes_size(mesh, axes) == 0 else None)
            sp = tuple(fixed)
        specs.append(P(*sp[:nd]))
    return jax.tree_util.tree_unflatten(treedef, specs)
