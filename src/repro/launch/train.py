"""Distributed SAFL training / serving steps for the production mesh.

The FL topology maps onto the mesh (DESIGN §3): one client group per
(pod, data) index; the *sketched* uplink is a psum of b-dim vectors executed
inside a shard_map (so sketching is shard-local along the model axis -- no
all-gather of the d-dim delta ever happens).  The FedOpt baseline step
transmits raw deltas (an O(d) all-reduce) for roofline comparison.

Two drivers share one round core (DESIGN §8): the per-round jitted step
(``make_safl_train_step``; one host dispatch per round) and the scanned
multi-round driver (``make_safl_scan_fn`` / ``run_mesh_scan``; R rounds as
one ``lax.scan`` OUTSIDE the shard_map with donated
``(params, opt_state, data_state, key)`` carries, device-side sharded batch
sampling via ``mesh_sampler``, and chunked on-device loss history).  Both
are bit-identical per round (tests/test_mesh_scan.py).

Run as a module for a real (CPU-scale) training run:
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke
"""

from __future__ import annotations

import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.adaptive import AdaConfig, apply_update, init_opt_state
from repro.core.packed import (PackingPlan, derive_generation_params,
                               derive_round_params, desk_flat,
                               make_sharded_packing_plan, pack_tree, sk_flat,
                               sk_packed_clients_wsum, unpack_tree)
from repro.core.safl import (SAFLConfig, chunk_clients, client_delta,
                             mask_weights, masked_mean, masked_mean_tree,
                             masked_psum_mean, resolve_microbatch)
from repro.core.sketch import (SKETCH_CHUNK_NUMEL, SketchConfig, desk_leaf,
                               desk_leaf_stacked, sk_leaf, sk_leaf_stacked)
from repro.fed.faults import corrupt_payload, take_rows
from repro.fed.faults import n_dropped as fault_n_dropped
from repro.fed.participation import check_policy_clients, is_weighted_mask
from repro.fed.robust import (carry_if_empty, divergence_flag,
                              sentinel_validity)
from repro.launch.driver import round_hook_kwargs
from repro.models.config import ModelConfig
from repro.models.model import decode_step, forward, loss_fn, param_shapes
from repro.models.sharding import param_pspecs

try:  # jax>=0.6 moved shard_map to the top level (axis_names/check_vma API)
    _shard_map_impl = jax.shard_map
    _NEW_SHARD_MAP = True
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map_impl  # type: ignore
    _NEW_SHARD_MAP = False


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=False):
    """Version shim: call sites use the new-jax kwargs; on jax 0.4.x we
    translate axis_names (manual axes) to the old ``auto`` complement and
    check_vma to check_rep."""
    if _NEW_SHARD_MAP:
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, **kw)
    kw = {"check_rep": check_vma}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map_impl(f, mesh, in_specs, out_specs, **kw)

Pytree = Any

# Test hook: force the jax-0.4.x cross_device client-delta formulation (the
# vmap fallback below) on the new stack too, so its bitwise parity against
# the partial-manual shard_map path can be asserted where both compile
# (tests/test_mesh_scan.py).
_FORCE_VMAP_CLIENT_DELTAS = False


def data_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def client_axes_of(mesh, topology: str) -> tuple[str, ...]:
    """Mesh axes that enumerate FL clients.

    cross_device: every (pod, data) index is a client (weights replicated
    over data, tensor-parallel over model).  cross_device_dp: same clients,
    but the client's OWN batch is data-parallel over the model axis with
    fully replicated weights (beyond-paper §Perf: trades per-layer TP
    activation collectives for one grad all-reduce -- the right regime for
    <=3B models).  cross_silo: each pod is one client (weights FSDP-sharded
    within the pod) -- the mapping for 100B+ configs."""
    if topology == "cross_silo":
        return tuple(a for a in ("pod",) if a in mesh.axis_names)
    return data_axes_of(mesh)


def num_clients_of(mesh, topology: str) -> int:
    axes = client_axes_of(mesh, topology)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# shard-local sketch -> b-dim psum -> desk  (the compressed uplink)
# ---------------------------------------------------------------------------

_SKETCH_CHUNK_NUMEL = SKETCH_CHUNK_NUMEL   # back-compat alias


def _collect(s, client_axes, w_loc, den):
    """The compressed uplink collective: ``pmean`` over the client axes when
    the round has no cohort mask, else the masked cohort mean fused into the
    SAME single collective (``core.safl.masked_psum_mean``: weighted local
    sum, one psum, divide by the global cohort weight / static HT
    denominator).  ``s`` keeps its leading local-client axis either way
    (size 1 after masking -- every shard holds the identical cohort mean),
    so the downstream desk/mean lowering is shared."""
    if w_loc is None:
        return jax.lax.pmean(s, client_axes) if client_axes else s
    return masked_psum_mean(s, w_loc, den, client_axes)


def _sketch_avg_desk_local(skcfg: SketchConfig, client_axes, deltas, key,
                           w_loc=None, den=None):
    """Per-leaf REFERENCE path, PER DEVICE inside shard_map.  deltas leaves:
    (G_loc, *local_shard).  Every cross-client collective in SAFL is the
    collect below -- b floats per tensor, not d.

    Leaves whose local shard exceeds SKETCH_CHUNK_NUMEL are sketched per
    slice of their leading (layer-stack) axis via lax.map: this bounds the
    hash/sign temporaries to one layer's worth and realizes the layer-wise
    sketching the paper's conclusion proposes.

    This is the ``plan=None`` fallback; the production route is the packed
    plan path below (same per-leaf fold_in chain, no per-round Python tree
    traversal), pinned bitwise equal by tests/test_mesh_scan.py.  Under a
    cohort mask (``w_loc``) the per-leaf route needs exactly one client row
    per shard (it folds the local client axis into the flattened leaf);
    multi-client shards take the packed route."""
    if w_loc is not None:
        g_loc = jax.tree_util.tree_leaves(deltas)[0].shape[0]
        if g_loc != 1:
            raise NotImplementedError(
                f"masked per-leaf sketch path needs one client row per "
                f"shard, got G_loc={g_loc}; use the packed plan route")
    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    out = []
    for i, leaf in enumerate(leaves):
        lk = jax.random.fold_in(key, i)
        lshape = leaf.shape[1:]                     # drop local client dim
        numel = 1
        for d in lshape:
            numel *= d
        n0 = lshape[0] if lshape else 1
        if numel > SKETCH_CHUNK_NUMEL and len(lshape) >= 2 and n0 > 1:
            vs = leaf.reshape(n0, numel // n0).astype(jnp.float32)
            s = sk_leaf_stacked(skcfg, lk, vs)                # (n0, b_sub)
            if w_loc is not None:   # masked uplink (one client row: s[None])
                s = masked_psum_mean(s[None], w_loc, den, client_axes)[0]
            elif client_axes:
                s = jax.lax.pmean(s, client_axes)  # <-- compressed uplink
            u = desk_leaf_stacked(skcfg, lk, s, numel // n0)
            out.append(u.reshape(leaf.shape))
            continue
        v = leaf.reshape(-1).astype(jnp.float32)
        s = sk_leaf(skcfg, lk, v)
        if w_loc is not None:
            s = masked_psum_mean(s[None], w_loc, den, client_axes)[0]
        elif client_axes:
            s = jax.lax.pmean(s, client_axes)      # <-- compressed uplink
        u = desk_leaf(skcfg, lk, s, v.shape[0])
        out.append(u.reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def _sketch_avg_desk_local_packed(plan: PackingPlan, client_axes, deltas,
                                  key, w_loc=None, den=None, mb=None,
                                  codec=None, ax_sizes=()):
    """Plan-routed shard-local sketch, PER DEVICE inside shard_map.

    The static layout (``plan``, built once OUTSIDE the trace from the
    shard-local leaf shapes) replaces the per-leaf Python loop: the round's
    operator is derived ONCE (shared by sk and desk, per-leaf fold_in tags
    identical to the reference path), each local client row is packed into
    one contiguous buffer and compressed in one fused pass, and the collect
    moves ONE (G_loc, b_total) payload.  A cohort mask (``w_loc``) fuses
    into that same collective (masked weighted sum before the psum) and
    shrinks the payload rows to the single cohort mean.  Being trace-free
    state -- only the round key is traced -- this is what lets the
    multi-round scan carry the sketch path with zero per-round host work
    (DESIGN §8).

    ``mb`` (optional) streams the shard-local sketch stage over chunks of
    ``mb`` client rows (DESIGN §12): a ``lax.scan`` folds the fused
    pack->sketch of each chunk into a running weighted sketch-sum, so the
    (G_loc, b_total) payload is never materialized -- peak sketch memory is
    O(mb * b_total).  The fold then needs exactly ONE psum of the
    (b_total,) partial sum + its scalar weight over the client axes
    (sketch linearity / mergeability, Property 1) before the single desk.
    A non-dividing tail chunk is zero-padded with zero weight, which is
    exact under the weighted sum.

    ``codec`` (static ``fed.codec.CodecConfig``, DESIGN.md §13) quantizes
    the shard-local weighted sketch-sum -- ONE (b_total,) row per client
    shard -- immediately before the single psum, so what the collective
    moves is the encoded payload.  Quantize-before-reduce is a deliberate
    bias trade (documented in §13): the per-shard quantizers are
    conditionally unbiased, but the server mean of quantized partial sums
    is not the quantization of the mean; the codec path is its own program
    family either way.  The rounding uniforms key off the FLAT SHARD INDEX
    (``ax_sizes`` aligns with ``client_axes``), so each shard draws an
    independent, reproducible stream."""
    rp = derive_round_params(plan, key)
    if codec is not None:
        from repro.fed.codec import encode_decode
        cid = jnp.int32(0)
        for ax, n in zip(client_axes, ax_sizes):
            cid = cid * n + jax.lax.axis_index(ax)

        def _enc(S):
            return encode_decode(codec, key, S[None],
                                 client_ids=cid[None])[0][0]
    if mb is not None:
        g_loc = jax.tree_util.tree_leaves(deltas)[0].shape[0]
        w = jnp.ones((g_loc,), jnp.float32) if w_loc is None else \
            w_loc.astype(jnp.float32)
        n_mb = -(-g_loc // mb)
        pad = n_mb * mb - g_loc
        dc = chunk_clients(deltas, mb, pad)          # (n_mb, mb, ...)
        wc = jnp.pad(w, (0, pad)).reshape(n_mb, mb)  # pad rows weigh 0

        def fold(carry, xc):
            S, W = carry
            dS, dW = sk_packed_clients_wsum(plan, rp, xc["d"], xc["w"])
            return (S + dS, W + dW), None

        S0 = jnp.zeros((plan.b_total,), jnp.float32)
        (S, W), _ = jax.lax.scan(fold, (S0, jnp.float32(0.0)),
                                 {"d": dc, "w": wc})
        if codec is not None:   # encode what the collective moves (§13)
            S = _enc(S)
        if client_axes:
            S = jax.lax.psum(S, client_axes)
            W = jax.lax.psum(W, client_axes)
        denom = jnp.float32(den) if den is not None else \
            jnp.maximum(W, jnp.float32(1.0))
        mbar = S / denom
        u = desk_flat(plan, rp, mbar)
        out = unpack_tree(plan, u, cast=False)
        return jax.tree.map(lambda x: x[None], out)  # (1, ...): cohort mean
    flat = jax.vmap(lambda t: pack_tree(plan, t))(deltas)   # (G_loc, d_loc)
    s = jax.vmap(lambda f: sk_flat(plan, rp, f))(flat)      # (G_loc, b_tot)
    if codec is not None:
        # codec family: weighted-local-sum -> quantize -> the ONE psum;
        # same restructure the mb fold uses, so both branches encode the
        # identical (b_total,) partial sum per shard
        g_loc = s.shape[0]
        w = (jnp.ones((g_loc,), jnp.float32) if w_loc is None
             else w_loc.astype(jnp.float32))
        S = jnp.sum(s.astype(jnp.float32) * w[:, None], axis=0)
        W = jnp.sum(w)
        S = _enc(S)
        if client_axes:
            S = jax.lax.psum(S, client_axes)
            W = jax.lax.psum(W, client_axes)
        denom = jnp.float32(den) if den is not None else \
            jnp.maximum(W, jnp.float32(1.0))
        u = desk_flat(plan, rp, S / denom)
        out = unpack_tree(plan, u, cast=False)
        return jax.tree.map(lambda x: x[None], out)  # (1, ...): cohort mean
    s = _collect(s, client_axes, w_loc, den)   # <-- compressed uplink
    u = jax.vmap(lambda p: desk_flat(plan, rp, p))(s)
    return jax.vmap(lambda f: unpack_tree(plan, f, cast=False))(u)


def sharded_sketch_avg_desk(mesh, skcfg: SketchConfig, pspecs, deltas, key,
                            topology: str = "cross_device", plan=None,
                            part_mask=None, microbatch=None, codec=None):
    """Sketch each client delta (shard-local), cohort-mean over client axes,
    desketch.

    deltas leaves: (G, *param_shape), G sharded over the client axes; param
    dims sharded per ``pspecs``.  Returns the update tree with param
    sharding.  ``plan`` (optional) is the shard-local ``PackingPlan`` from
    ``core.packed.make_sharded_packing_plan``: when given, leaf sketching
    runs through the fused packed engine (one dispatch, operator derived
    once); ``plan=None`` keeps the per-leaf reference loop.  Both produce
    identical values for shards below the layer-chunk threshold
    (tests/test_mesh_scan.py pins this bitwise).

    ``part_mask`` (optional) is a repro.fed participation mask over the G
    clients -- a (G,) 0/1 array, or the weighted dict form of
    ``ImportanceParticipation``.  The mask is evaluated OUTSIDE the
    shard_map (scan body); here its weight vector enters sharded over the
    client axes and the aggregation becomes the masked cohort mean, fused
    into the same single collective the unmasked path uses
    (``core.safl.masked_psum_mean``).  An all-ones mask is pinned bitwise
    to ``part_mask=None``.

    ``microbatch`` (optional) streams the SHARD-LOCAL sketch stage over
    chunks of that many client rows (DESIGN §12): instead of materializing
    the (G_loc, b_total) payload, each shard folds per-chunk weighted
    sketch-sums and the collective shrinks to one psum of a (b_total,)
    partial sum plus a scalar weight.  Requires the packed ``plan``.
    ``None`` or >= the shard-local cohort keeps the materialized path
    bitwise untouched; the streamed fold is its own program family, equal
    to the materialized one up to float summation order.

    ``codec`` (static ``fed.codec.CodecConfig``) quantizes each shard's
    weighted sketch-sum before the one psum (DESIGN.md §13; requires the
    packed ``plan``; per-client error feedback does not exist at shard
    granularity, so ``codec.error_feedback`` raises -- pass
    ``CodecConfig(..., error_feedback=False)``).  ``codec=None`` routes at
    Python level, keeping the pinned programs byte-identical."""
    client_axes = client_axes_of(mesh, topology)
    lead = client_axes if client_axes else None
    in_specs = jax.tree.map(
        lambda ps: P(*((lead,) + tuple(ps))), pspecs,
        is_leaf=lambda x: isinstance(x, P))
    out_specs = pspecs
    mb = None
    if microbatch is not None:
        g = jax.tree.leaves(deltas)[0].shape[0]
        g_loc = g // max(_axes_size(mesh, client_axes), 1)
        mb = resolve_microbatch(microbatch, g_loc)
        if mb is not None and plan is None:
            raise ValueError(
                "microbatch streaming needs the packed plan route; build "
                "one with make_sharded_packing_plan (per-leaf reference "
                "path folds the client axis leaf-by-leaf and cannot "
                "stream)")
    if codec is not None:
        if plan is None:
            raise ValueError(
                "the mesh payload codec needs the packed plan route; build "
                "one with make_sharded_packing_plan")
        if codec.error_feedback:
            raise ValueError(
                "the mesh uplink quantizes SHARD-LOCAL partial sums; "
                "per-client error feedback does not exist at that "
                "granularity -- use CodecConfig(..., error_feedback=False)")
    if plan is not None:
        fn = functools.partial(_sketch_avg_desk_local_packed, plan,
                               client_axes)
        if mb is not None:
            fn = functools.partial(fn, mb=mb)
        if codec is not None:
            fn = functools.partial(
                fn, codec=codec,
                ax_sizes=tuple(mesh.shape[ax] for ax in client_axes))
    else:
        fn = functools.partial(_sketch_avg_desk_local, skcfg, client_axes)

    if part_mask is None:
        def local(d, k):
            upd = fn(d, k)
            # fold the local client axis (size 1 when G == #client groups;
            # mean over it otherwise)
            return jax.tree.map(lambda u: u.mean(axis=0), upd)

        return shard_map(local, mesh=mesh,
                         in_specs=(in_specs, P()), out_specs=out_specs,
                         check_vma=False)(deltas, key)

    w = mask_weights(part_mask)                              # (G,)
    den = float(part_mask["den"]) if is_weighted_mask(part_mask) else None

    def local_masked(d, k, wl):
        upd = fn(d, k, wl, den)         # leaves (1, ...): the cohort mean
        return jax.tree.map(lambda u: u.mean(axis=0), upd)

    return shard_map(local_masked, mesh=mesh,
                     in_specs=(in_specs, P(), P(lead)), out_specs=out_specs,
                     check_vma=False)(deltas, key, w)


def _sharded_sketch_guarded(mesh, plan: PackingPlan, pspecs, deltas, key,
                            topology: str, part_mask, fault_spec, sentinel):
    """The compressed uplink with the DESIGN.md §10 fusion chain applied
    inside the sketch shard_map: faults -> sentinels -> participation mask
    -> ONE payload psum.

    The fault spec and the mask enter REPLICATED (tiny (G,) vectors); each
    shard corrupts/vets its own client rows (``rows`` as in the staleness
    buffer) and the sentinel's cross-shard agreement costs one extra psum of
    two (G,) stats arrays over ALL mesh axes (``fed.robust
    .sentinel_validity`` -- a client is only valid if every model shard of
    its payload row is, or shards would divide by different cohort weights
    and desynchronize).  The payload itself still moves through exactly one
    psum over the client axes, with the fused effective weights.

    Returns ``(update_tree, eff_w (G,), n_rejected)`` -- the effective
    weight vector is what the caller's loss metric and empty-cohort
    fallback key off."""
    client_axes = client_axes_of(mesh, topology)
    all_axes = tuple(mesh.axis_names)
    G = num_clients_of(mesh, topology)
    lead = client_axes if client_axes else None
    in_specs = jax.tree.map(
        lambda ps: P(*((lead,) + tuple(ps))), pspecs,
        is_leaf=lambda x: isinstance(x, P))
    w0 = (jnp.ones((G,), jnp.float32) if part_mask is None
          else mask_weights(part_mask))
    den = float(part_mask["den"]) if is_weighted_mask(part_mask) else None

    def local(*a):
        d_tree, k, w_full = a[:3]
        spec = a[3] if fault_spec is not None else None
        rp = derive_round_params(plan, k)
        flat = jax.vmap(lambda tr: pack_tree(plan, tr))(d_tree)
        s = jax.vmap(lambda f: sk_flat(plan, rp, f))(flat)   # (G_loc, b_loc)
        g_loc = s.shape[0]
        cid = 0
        for ax in client_axes:
            cid = cid * mesh.shape[ax] + jax.lax.axis_index(ax)
        rows = cid * g_loc + jnp.arange(g_loc)
        w_arr = w_full
        if spec is not None:
            s = corrupt_payload(take_rows(spec, rows), s)
            w_arr = w_full * spec["arrive"]
        if sentinel is not None:
            valid, s, n_rej = sentinel_validity(
                sentinel, s, rows, w_arr, G, all_axes)
            w_eff = w_arr * valid.astype(jnp.float32)
        else:
            n_rej = jnp.float32(0.0)
            w_eff = w_arr
        wl = w_eff[rows]
        sw = jnp.sum(s * wl[:, None], axis=0, keepdims=True)
        if client_axes:
            sw = jax.lax.psum(sw, client_axes)   # <-- the ONE payload psum
        if den is not None:     # static Horvitz-Thompson denominator
            mean = sw / jnp.asarray(den, sw.dtype)
        else:                   # w_eff is replicated: no weight psum needed
            mean = sw / jnp.maximum(jnp.sum(w_eff), 1.0).astype(sw.dtype)
        u = desk_flat(plan, rp, mean[0])
        return unpack_tree(plan, u, cast=False), w_eff, n_rej

    args = [deltas, key, w0]
    specs = [in_specs, P(), P()]
    if fault_spec is not None:
        args.append(fault_spec)
        specs.append({k: P() for k in fault_spec})
    return shard_map(local, mesh=mesh, in_specs=tuple(specs),
                     out_specs=(pspecs, P(), P()),
                     check_vma=False)(*args)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def client_deltas_sharded(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                          topology: str, params, batch, eta):
    """Per-client local training, manual over the client axes and AUTO/GSPMD
    over the model (+FSDP) axes: each client group runs K local SGD steps on
    its own replica with zero cross-client communication.  Returns
    (deltas (G, *param), losses (G,))."""
    from repro.models.sharding import manual_axes
    loss = lambda p, b: loss_fn(model_cfg, p, b)
    caxes = client_axes_of(mesh, topology)

    # in dp mode all model-axis hints are disabled so GSPMD freely
    # propagates the batch-over-model sharding
    haxes = caxes + (("model",) if topology == "cross_device_dp" else ())

    def body(p, b_local):
        with manual_axes(haxes):
            mb = jax.tree.map(lambda x: x[0], b_local)      # drop local G=1
            if topology == "cross_device_dp":
                mb = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, P(None, "model") if x.ndim >= 2 else P()), mb)
            delta, l = client_delta(safl_cfg, loss, p, mb, eta)
        delta = jax.tree.map(lambda d: d[None], delta)
        return delta, l[None]

    if not caxes:                                            # 1 client total
        return body(params, batch)

    if topology == "cross_silo":
        # XLA's SPMD partitioner cannot handle partial-manual shard_map over
        # the pod axis of a 3-axis mesh (hard CHECK failure); the vmap
        # formulation partitions cleanly here because the client count (2
        # pods) matches the pod axis exactly and weights carry no pod axis.
        with manual_axes(()):
            def one(mb):
                return client_delta(safl_cfg, loss, params, mb, eta)
            deltas, losses = jax.vmap(one)(batch)
        return deltas, losses

    if not _NEW_SHARD_MAP or _FORCE_VMAP_CLIENT_DELTAS:
        # jax 0.4.x: the partial-manual shard_map below hard-crashes the
        # bundled XLA (IsManualSubgroup CHECK) as soon as a sharding hint
        # appears inside the manual region.  The cross_silo-style vmap
        # formulation runs the SAME per-client program -- identical
        # fold_in/grad/reduction chain per client, clients independent, G
        # sharded over the client axes by GSPMD instead of manually -- so
        # trajectories match the shard_map path bitwise (asserted on the
        # new stack, where both compile, by tests/test_mesh_scan.py); this
        # is what lets the full mesh suite run on both jax stacks
        # (ROADMAP: cross_device scan on jax 0.4.x).
        vmap_haxes = ()
        if topology == "cross_device_dp":
            # the in-body hint (mb data-parallel over the model axis) moves
            # outside the vmap: same spec, one leading G dim earlier; model-
            # axis hints stay disabled so GSPMD can propagate batch-over-
            # model freely, exactly like the shard_map body
            vmap_haxes = ("model",)
            batch = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, P(*((caxes, None, "model")
                           + (None,) * (x.ndim - 3)))) if x.ndim >= 3 else x,
                batch)
        with manual_axes(vmap_haxes):
            def one(mb):
                return client_delta(safl_cfg, loss, params, mb, eta)
            deltas, losses = jax.vmap(one)(batch)
        return deltas, losses

    lead = P(caxes)
    b_specs = jax.tree.map(lambda x: lead, batch)
    d_specs = jax.tree.map(lambda x: lead, params)
    return shard_map(body, mesh=mesh,
                     in_specs=(P(), b_specs),
                     out_specs=(d_specs, lead),
                     axis_names=set(caxes), check_vma=False)(params, batch)


def _mesh_pspecs(model_cfg: ModelConfig, topology: str):
    abstract = jax.eval_shape(
        lambda: jax.tree.map(lambda s: jnp.zeros(s, model_cfg.dtype),
                             param_shapes(model_cfg),
                             is_leaf=lambda x: isinstance(x, tuple)))
    if topology == "cross_device_dp":
        pspecs = jax.tree.map(lambda p: P(*((None,) * len(p))),
                              param_pspecs(abstract),
                              is_leaf=lambda x: isinstance(x, P))
    else:
        pspecs = param_pspecs(abstract, fsdp=(topology == "cross_silo"))
    return abstract, pspecs


def _mesh_plan(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
               topology: str):
    """(abstract, pspecs, plan) for one mesh round family.

    The shard-local ``PackingPlan`` is built HERE, once, outside any trace
    (``core.packed.make_sharded_packing_plan``), so only the round operator
    (``derive_round_params``) depends on the round key -- the sketch path is
    trace-free state a multi-round ``lax.scan`` can thread through its
    carry.  Models with a local shard above ``SKETCH_CHUNK_NUMEL`` keep the
    per-leaf reference path instead (``plan=None``): its layer-chunked
    lax.map bounds the operator temporaries to one layer slice, which the
    whole-leaf packed route would not."""
    from repro.core.packed import shard_local_abstract
    abstract, pspecs = _mesh_pspecs(model_cfg, topology)
    plan = None
    if safl_cfg.sketch.kind != "none":
        local_abs = shard_local_abstract(abstract, pspecs, dict(mesh.shape))
        if all(int(np.prod(l.shape)) <= SKETCH_CHUNK_NUMEL
               for l in jax.tree.leaves(local_abs)):
            plan = make_sharded_packing_plan(safl_cfg.sketch, abstract,
                                             pspecs, dict(mesh.shape))
    return abstract, pspecs, plan


def _buffer_specs(mesh, topology: str):
    """Partition specs of the mesh staleness ring buffer.

    ``buf`` is the global client-major payload ring -- generation dim
    unsharded, client dim over the client axes, the packed payload dim over
    every remaining mesh axis (each model/FSDP shard owns its slice of the
    shard-local sketch, mirroring how the payload exists inside the sketch
    shard_map).  ``bufw`` drops the payload dim."""
    caxes = client_axes_of(mesh, topology)
    other = tuple(a for a in mesh.axis_names if a not in caxes)
    return (P(None, caxes, other if other else None), P(None, caxes)), caxes


def init_mesh_async_state(model_cfg: ModelConfig, safl_cfg: SAFLConfig,
                          acfg, mesh, params,
                          topology: str = "cross_device") -> dict:
    """Server opt state + the mesh staleness ring buffer (scan-carry
    resident), for ``run_mesh_scan(..., buffer=acfg)`` /
    ``make_safl_train_step(..., buffer=acfg)``.

    The ring holds the last ``D = max_delay + 1`` generation rounds'
    per-client ``(G, b_total)`` sketch payloads (sharded: clients over the
    client axes, payload over the model/FSDP axes -- see
    ``_buffer_specs``) plus the matching 0/1 cohort weights."""
    _, _, plan = _mesh_plan(model_cfg, safl_cfg, mesh, topology)
    if plan is None:
        raise ValueError(
            "the mesh staleness buffer stores packed (G, b_total) sketch "
            "payloads: it needs the packed plan route (sketch.kind != "
            "'none' and every local shard <= SKETCH_CHUNK_NUMEL)")
    (buf_spec, bufw_spec), caxes = _buffer_specs(mesh, topology)
    if not caxes:
        raise ValueError("the mesh staleness buffer needs client mesh axes")
    G = num_clients_of(mesh, topology)
    n_other = 1
    for a in mesh.axis_names:
        if a not in caxes:
            n_other *= mesh.shape[a]
    D = acfg.buffer_rounds
    buf = jax.device_put(
        jnp.zeros((D, G, plan.b_total * n_other), jnp.float32),
        NamedSharding(mesh, buf_spec))
    bufw = jax.device_put(jnp.zeros((D, G), jnp.float32),
                          NamedSharding(mesh, bufw_spec))
    return {"opt": init_opt_state(safl_cfg.server, params),
            "buf": buf, "bufw": bufw}


def sharded_sketch_buffered(mesh, acfg, plan: PackingPlan, pspecs, deltas,
                            buf, bufw, round_key, base_key, t,
                            topology: str = "cross_device", part_mask=None,
                            fault_spec=None, sentinel=None):
    """FedBuff-style staleness-buffered uplink on the mesh (DESIGN §9).

    One shard_map over the whole mesh: sketch the local client rows with
    round t's operator, push the ``(G_loc, b_total)`` payload (and the
    round's cohort weights) into the ring slot ``t % D``, recompute every
    generation's arrivals from the deterministic delay policy
    (``fed.async_buffer.arrival_weight`` -- pure in (g, c, seed), nothing
    stored but payloads), reduce each arriving generation in ITS OWN sketch
    space, run ONE fused psum over the client axes for all generations'
    partial sums, and desketch each generation with its own operator
    re-derived from ``fold_in(base_key, g)`` INSIDE the shard_map
    (``core.packed.derive_generation_params``).  Returns
    ``(update_tree, buf, bufw)``.

    With ``delay="zero"`` the d > 0 arrival groups are statically empty and
    the round lowers to the synchronous masked path -- the bitwise parity
    pin of tests/test_mesh_scan.py.

    ``fault_spec``/``sentinel`` (DESIGN.md §10) corrupt and then vet the
    payload BEFORE the push -- the ring must never store a poisoned row, or
    it would re-emit it at every later pop of that generation; dropped and
    rejected clients store weight 0, exactly like non-participation.  The
    guarded call additionally returns ``(W, n_rejected)``:
    ``(update_tree, buf, bufw, W, n_rejected)``."""
    from repro.fed.async_buffer import arrival_weight
    if is_weighted_mask(part_mask):
        raise TypeError(
            "the mesh staleness buffer stores 0/1 cohort masks per "
            "generation; weighted (importance-sampling) masks are not "
            "supported -- use a 0/1 participation policy")
    client_axes = client_axes_of(mesh, topology)
    (buf_spec, bufw_spec), _ = _buffer_specs(mesh, topology)
    if not client_axes:
        raise ValueError("the mesh staleness buffer needs client mesh axes")
    G = num_clients_of(mesh, topology)
    D = acfg.buffer_rounds
    lead = client_axes
    in_specs = jax.tree.map(
        lambda ps: P(*((lead,) + tuple(ps))), pspecs,
        is_leaf=lambda x: isinstance(x, P))

    guarded = fault_spec is not None or sentinel is not None
    all_axes = tuple(mesh.axis_names)

    def local(*a):
        d_tree, buf, bufw, rk, base, t, wv = a[:7]
        spec = a[7] if fault_spec is not None else None
        rp_t = derive_round_params(plan, rk)
        flat = jax.vmap(lambda tr: pack_tree(plan, tr))(d_tree)
        sks = jax.vmap(lambda f: sk_flat(plan, rp_t, f))(flat) \
            .astype(jnp.float32)                        # (G_loc, b_loc)
        g_loc = sks.shape[0]
        # global client ids of this shard's rows (row-major over the client
        # axes, matching how shard_map splits the leading G dim)
        cid = 0
        for a_ in client_axes:
            cid = cid * mesh.shape[a_] + jax.lax.axis_index(a_)
        rows = cid * g_loc + jnp.arange(g_loc)
        if not guarded:
            w_loc, n_rej = wv, None      # wv entered sharded over clients
        else:
            # wv entered REPLICATED: faults/sentinels fuse into the full
            # (G,) weight vector BEFORE the push (§10 order), so the ring
            # only ever stores vetted payloads and their fused weights
            w_full = wv
            if spec is not None:
                sks = corrupt_payload(take_rows(spec, rows), sks)
                w_full = w_full * spec["arrive"]
            if sentinel is not None:
                valid, sks, n_rej = sentinel_validity(
                    sentinel, sks, rows, w_full, G, all_axes)
                w_full = w_full * valid.astype(jnp.float32)
            else:
                n_rej = jnp.float32(0.0)
            w_loc = w_full[rows]
        # -- push: generation t claims slot t % D (its previous tenant,
        # generation t - D, fully drained by round t - 1) --
        slot_t = jnp.mod(t, D)
        buf = buf.at[slot_t].set(sks)
        bufw = bufw.at[slot_t].set(w_loc)
        # -- pop: per-generation shard-local partial sums; the d = 0 group
        # reads the just-pushed sks/w_loc directly (CSE; with the "zero"
        # delay policy the d > 0 groups are statically empty, so the round
        # constant-folds to the synchronous masked program) --
        weighted = []                   # (W_loc, S_loc, rp_g) per delay
        for d in range(D):              # static: D is a config constant
            g = t - d
            if acfg.delay == "zero" and d > 0:
                continue
            if d == 0:
                payload, w_in = sks, w_loc
            else:
                payload = buf[jnp.mod(g, D)]
                w_in = bufw[jnp.mod(g, D)]
            w = w_in * arrival_weight(acfg, g, d, G)[rows]
            S_loc = jnp.sum(w[:, None] * payload, axis=0)   # (b_loc,)
            rp_g = rp_t if d == 0 else derive_generation_params(plan, base, g)
            weighted.append((jnp.sum(w), S_loc, rp_g))
        # ONE fused collective for every generation's partial sums: D
        # payloads of b_total floats -- still sketch-dimensional uplink
        S_stack = jnp.stack([s for _, s, _ in weighted])
        W_stack = jnp.stack([wd for wd, _, _ in weighted])
        S_stack, W_stack = jax.lax.psum((S_stack, W_stack), client_axes)
        W = jnp.sum(W_stack)
        W_safe = jnp.where(W > 0, W, 1.0)   # no arrivals -> zero update
        upd_flat = sum(desk_flat(plan, rp_g, S_stack[i] / W_safe)
                       for i, (_, _, rp_g) in enumerate(weighted))
        update = unpack_tree(plan, upd_flat, cast=False)
        if guarded:
            return update, buf, bufw, W, n_rej
        return update, buf, bufw

    w = part_mask if part_mask is not None \
        else jnp.ones((G,), jnp.float32)
    args = [deltas, buf, bufw, round_key, base_key, t, w]
    specs = [in_specs, buf_spec, bufw_spec, P(), P(), P(),
             P() if guarded else P(lead)]
    out_specs = (pspecs, buf_spec, bufw_spec)
    if guarded:
        out_specs = out_specs + (P(), P())
    if fault_spec is not None:
        args.append(fault_spec)
        specs.append({k: P() for k in fault_spec})
    return shard_map(local, mesh=mesh, in_specs=tuple(specs),
                     out_specs=out_specs, check_vma=False)(*args)


def _make_round_core(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                     topology: str = "cross_device", *, participation=None,
                     buffer=None, faults=None, sentinel=None,
                     telemetry=None, microbatch=None, codec=None):
    """The typed-key SAFL mesh round:
    ``core(params, state, batch, round_key, **hook_kwargs) ->
    (params, state, loss_or_metrics)``.

    The static sketch layout comes from ``_mesh_plan`` (built once, outside
    any trace); ``make_safl_train_step`` wraps this with the key_data
    calling convention and ``make_safl_scan_fn`` scans it.  The repro.fed
    hooks ride the same core for both drivers: ``participation`` masks the
    server aggregation over the round's sampled cohort (mask evaluated by
    the CALLER in the scan body, handed in as ``part_mask``), ``buffer``
    (an ``fed.async_buffer.AsyncConfig``) swaps the synchronous uplink for
    the mesh staleness ring buffer, with ``state`` the dict from
    ``init_mesh_async_state`` and ``t``/``base_key`` threaded in by the
    caller (``launch.driver.round_hook_kwargs``), and ``faults``/
    ``sentinel`` (``fed.faults`` / ``fed.robust``, DESIGN.md §10) inject
    and contain payload faults inside the sketch shard_map (the caller
    threads the traced per-round ``fault_spec``).  Hookless and
    participation/buffer-only cores return a loss SCALAR (the PR-4/PR-5
    contract, bitwise-pinned); fault/sentinel cores return a metrics dict
    (``loss`` + ``n_dropped``/``n_rejected``/``diverged`` counters).

    ``telemetry`` (static ``repro.obs.Telemetry``) switches any core to the
    metrics-dict return and adds the probe scalars (DESIGN.md §11).  The
    Δ̄-based probes are computed OUTSIDE the sketch shard_map from the
    sharded global delta tree, so GSPMD inserts the O(d) reductions they
    need -- an explicitly opt-in cost the compressed uplink never pays.
    ``telemetry=None`` (the default) leaves every program byte-identical to
    the pinned trajectories.

    ``microbatch`` (static, optional) streams the shard-local sketch stage
    over client-row chunks (DESIGN §12) -- plain (hookless /
    participation-only) sketched cores only: the staleness buffer and the
    fault/sentinel guard need the materialized per-client payload rows, and
    telemetry probes read the materialized delta tree, so combining them
    raises.  ``None`` / >= the shard-local cohort is the materialized path,
    bitwise-pinned.

    ``codec`` (static ``fed.codec.CodecConfig``, DESIGN.md §13) quantizes
    each shard's sketch partial-sum before the one psum -- plain sketched
    cores only (buffer/faults/sentinel operate on per-client payload rows
    that the shard-sum codec never sees; telemetry probes are computed
    from unquantized deltas; fedopt has no sketch payload), and only
    without per-client error feedback (shard granularity).  A codec core
    returns a metrics dict whose ``uplink_bits`` is the MEASURED encoded
    size: one payload row per client shard crossing the collective."""
    abstract, pspecs, plan = _mesh_plan(model_cfg, safl_cfg, mesh, topology)
    G = num_clients_of(mesh, topology)
    guarded = faults is not None or sentinel is not None
    if codec is not None:
        if buffer is not None or guarded:
            raise NotImplementedError(
                "the mesh payload codec quantizes shard-local partial "
                "sums; the staleness buffer and the fault/sentinel guard "
                "operate on materialized per-client payload rows -- run "
                "those hooks without codec=")
        if telemetry is not None:
            raise ValueError(
                "telemetry probes read the unquantized delta tree; drop "
                "telemetry= or codec=")
        if safl_cfg.sketch.kind == "none":
            raise ValueError(
                "the payload codec quantizes the packed sketch uplink; "
                "fedopt (sketch.kind='none') has no sketch payload")
        if plan is None:
            raise ValueError(
                "the mesh payload codec needs the packed plan route "
                "(every local shard <= SKETCH_CHUNK_NUMEL)")
        if codec.error_feedback:
            raise ValueError(
                "the mesh uplink quantizes SHARD-LOCAL partial sums; "
                "per-client error feedback does not exist at that "
                "granularity -- use CodecConfig(..., error_feedback=False)")
    if microbatch is not None:
        resolve_microbatch(microbatch, G)   # reject mb <= 0 at build time
        if buffer is not None or guarded:
            raise NotImplementedError(
                "mesh microbatch streaming folds the payload before any "
                "per-client row exists; the staleness buffer and the "
                "fault/sentinel guard operate on materialized payload "
                "rows -- run those hooks without microbatch=")
        if telemetry is not None:
            raise ValueError(
                "telemetry probes read the materialized cohort delta "
                "tree; drop telemetry= or microbatch=")
        if safl_cfg.sketch.kind == "none":
            raise ValueError(
                "mesh microbatch streaming folds in sketch space; "
                "fedopt (sketch.kind='none') has no sketch payload")
        if plan is None:
            raise ValueError(
                "mesh microbatch streaming needs the packed plan route "
                "(every local shard <= SKETCH_CHUNK_NUMEL)")
    if participation is not None:
        check_policy_clients(participation, G, "mesh driver")
    if guarded:
        if safl_cfg.sketch.kind == "none":
            raise ValueError(
                "fault injection / payload sentinels act on the packed "
                "sketch uplink; fedopt (sketch.kind='none') has no sketch "
                "payload")
        if plan is None:
            raise ValueError(
                "the mesh fault/sentinel hooks need the packed plan route "
                "(every local shard <= SKETCH_CHUNK_NUMEL)")
        if faults is not None and faults.num_clients != G:
            raise ValueError(
                f"fault policy covers {faults.num_clients} clients, the "
                f"mesh topology has {G}")
    if buffer is not None:
        if safl_cfg.sketch.kind == "none":
            raise ValueError("the staleness buffer aggregates in sketch "
                             "space; fedopt (sketch.kind='none') cannot "
                             "ride it")
        if plan is None:
            raise ValueError(
                "the mesh staleness buffer needs the packed plan route "
                "(every local shard <= SKETCH_CHUNK_NUMEL)")

    def core(params, state, batch, key, *, t=None, base_key=None,
             part_mask=None, fault_spec=None):
        eta = jnp.asarray(safl_cfg.client_lr, jnp.float32)
        deltas, losses = client_deltas_sharded(
            model_cfg, safl_cfg, mesh, topology, params, batch, eta)

        def _tel(m, *, update, st, mask):
            # telemetry=None is the identity on the return value, so the
            # disabled-path programs stay byte-identical (static gate)
            if telemetry is None:
                return m
            from repro.obs.telemetry import telemetry_probes
            m = dict(m) if isinstance(m, dict) else {"loss": m}
            m.update(telemetry_probes(telemetry, deltas=deltas,
                                      update=update, part_mask=mask,
                                      state=st))
            return m

        if buffer is not None:
            if not guarded:
                update, buf, bufw = sharded_sketch_buffered(
                    mesh, buffer, plan, pspecs, deltas, state["buf"],
                    state["bufw"], key, base_key, t, topology,
                    part_mask=part_mask)
                params, opt = apply_update(
                    safl_cfg.server, state["opt"], params, update)
                new_state = {"opt": opt, "buf": buf, "bufw": bufw}
                return (params, new_state,
                        _tel(masked_mean(losses, part_mask), update=update,
                             st=new_state, mask=part_mask))
            update, buf, bufw, W, n_rej = sharded_sketch_buffered(
                mesh, buffer, plan, pspecs, deltas, state["buf"],
                state["bufw"], key, base_key, t, topology,
                part_mask=part_mask, fault_spec=fault_spec,
                sentinel=sentinel)
            new_params, opt = apply_update(
                safl_cfg.server, state["opt"], params, update)
            loss = masked_mean(losses, part_mask)
            metrics = {"loss": loss, "arrival_weight": W,
                       "n_rejected": n_rej}
            if fault_spec is not None:
                metrics["n_dropped"] = fault_n_dropped(fault_spec, part_mask)
            if sentinel is not None:
                # no-arrival round: carry the server through unchanged
                new_params, opt = jax.tree.map(
                    lambda nw, o: jnp.where(W > 0, nw, o),
                    (new_params, opt), (params, state["opt"]))
                metrics["diverged"] = divergence_flag(sentinel, loss)
            new_state = {"opt": opt, "buf": buf, "bufw": bufw}
            return (new_params, new_state,
                    _tel(metrics, update=update, st=new_state,
                         mask=part_mask))
        if guarded:
            update, eff_w, n_rej = _sharded_sketch_guarded(
                mesh, plan, pspecs, deltas, key, topology, part_mask,
                fault_spec, sentinel)
            eff_mask = ({**part_mask, "w": eff_w}
                        if is_weighted_mask(part_mask) else eff_w)
            new_params, new_state = apply_update(
                safl_cfg.server, state, params, update)
            loss = masked_mean(losses, eff_mask)
            metrics = {"loss": loss, "n_rejected": n_rej}
            if fault_spec is not None:
                metrics["n_dropped"] = fault_n_dropped(fault_spec, part_mask)
            if sentinel is not None:
                new_params, new_state = carry_if_empty(
                    eff_mask, (new_params, new_state), (params, state))
                metrics["diverged"] = divergence_flag(sentinel, loss)
            return new_params, new_state, _tel(metrics, update=update,
                                               st=new_state, mask=eff_mask)
        if safl_cfg.sketch.kind == "none":
            # FedOpt baseline: raw-delta mean = O(d) all-reduce over clients
            if part_mask is None:
                update = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas)
            else:
                update = masked_mean_tree(deltas, part_mask)
        else:
            update = sharded_sketch_avg_desk(
                mesh, safl_cfg.sketch, pspecs, deltas, key, topology,
                plan=plan, part_mask=part_mask, microbatch=microbatch,
                codec=codec)
        params, state = apply_update(safl_cfg.server, state, params, update)
        loss = (jnp.mean(losses) if part_mask is None
                else masked_mean(losses, part_mask))
        if codec is not None:
            # measured wire size: one encoded (b_total,) partial-sum row
            # per client shard crosses the collective (a static count --
            # masked-out clients still contribute their zeroed rows to the
            # shard sum, so every shard transmits)
            n_shards = 1
            for ax in client_axes_of(mesh, topology):
                n_shards *= mesh.shape[ax]
            m = {"loss": loss, "uplink_bits": jnp.float32(
                codec.payload_bits(plan.b_total) * n_shards)}
            return params, state, m
        return params, state, _tel(loss, update=update, st=state,
                                   mask=part_mask)

    return core, pspecs


def make_safl_train_step(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                         topology: str = "cross_device", *,
                         participation=None, buffer=None, faults=None,
                         sentinel=None, telemetry=None, microbatch=None,
                         codec=None):
    """SAFL round on the mesh.  batch leaves: (G, K, mb, ...) with G = number
    of FL clients (data-parallel groups or pods, per ``topology``).

    Without hooks the step keeps the PR-4 signature
    ``step(params, opt_state, batch, key_data)`` where ``key_data`` is the
    ROUND key's data.  With any repro.fed hook
    (``participation=``/``buffer=``/``faults=``/``sentinel=``) the step
    needs the absolute round index and the run's base key --
    ``step(params, state, batch, base_key_data, t)`` -- and derives the
    round key as ``fold_in(base, t)`` itself, the exact chain the scanned
    driver uses; ``state`` is the ``init_mesh_async_state`` dict when
    buffered.  Fault/sentinel steps return a metrics DICT in place of the
    loss scalar (see ``_make_round_core``)."""
    core, pspecs = _make_round_core(model_cfg, safl_cfg, mesh, topology,
                                    participation=participation,
                                    buffer=buffer, faults=faults,
                                    sentinel=sentinel, telemetry=telemetry,
                                    microbatch=microbatch, codec=codec)
    hooked = (participation is not None or buffer is not None
              or faults is not None or sentinel is not None)
    if not hooked:
        def step(params, opt_state, batch, key_data):
            return core(params, opt_state, batch,
                        jax.random.wrap_key_data(key_data))
    else:
        def step(params, state, batch, key_data, t):
            base = jax.random.wrap_key_data(key_data)
            kw, _ = round_hook_kwargs(t, base, None, participation,
                                      buffer is not None, faults)
            return core(params, state, batch, jax.random.fold_in(base, t),
                        **kw)

    return step, pspecs


def _fedopt_cfg(safl_cfg: SAFLConfig) -> SAFLConfig:
    return SAFLConfig(sketch=SketchConfig(kind="none"),
                      server=safl_cfg.server,
                      client_lr=safl_cfg.client_lr,
                      local_steps=safl_cfg.local_steps,
                      remat_local=safl_cfg.remat_local)


def make_fedopt_train_step(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                           topology: str = "cross_device", *,
                           participation=None, buffer=None, faults=None,
                           sentinel=None, telemetry=None, microbatch=None,
                           codec=None):
    """Uncompressed FedOPT baseline: raw-delta mean = O(d) all-reduce."""
    return make_safl_train_step(model_cfg, _fedopt_cfg(safl_cfg), mesh,
                                topology, participation=participation,
                                buffer=buffer, faults=faults,
                                sentinel=sentinel, telemetry=telemetry,
                                microbatch=microbatch, codec=codec)


# ---------------------------------------------------------------------------
# multi-pod scanned mesh driver: scan OUTSIDE the shard_map round (DESIGN §8)
# ---------------------------------------------------------------------------

def mesh_sampler(mesh, sampler, topology: str = "cross_device"):
    """Wrap a device sampler (``init_state()/sample(state, t)``) so its
    ``(G, K, mb, ...)`` batches land sharded on the mesh per
    ``batch_pspecs`` -- G over the client axes, mb over ``data`` in
    cross_silo.  The constraint is pure layout (tokens bitwise unchanged),
    so mesh and single-host trajectories stay comparable."""
    from repro.data.device import ShardedSampler
    st = jax.eval_shape(sampler.init_state)
    babs = jax.eval_shape(sampler.sample, st,
                          jax.ShapeDtypeStruct((), jnp.int32))[1]
    shardings = to_shardings(mesh, batch_pspecs(babs, mesh, topology))
    return ShardedSampler(sampler, shardings)


def make_safl_scan_fn(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                      topology: str = "cross_device", *, sampler,
                      num_rounds: int, donate: bool = True,
                      participation=None, buffer=None, faults=None,
                      sentinel=None, telemetry=None, microbatch=None,
                      codec=None):
    """Jit ``num_rounds`` SAFL mesh rounds as ONE ``lax.scan`` dispatch.

    The scan sits OUTSIDE the shard_map round: each scanned step draws its
    batch on device (``sampler.sample(data_state, t)``, sharded via
    ``mesh_sampler``), derives the round key as ``fold_in(key, t)`` inside
    the scan body, and runs the same round core the per-round jitted step
    uses -- so scanned and per-round mesh trajectories are bit-identical
    (tests/test_mesh_scan.py).  The ``(params, opt_state, data_state, key)``
    carry is DONATED: large models update in place across chunks, and the
    host pays one dispatch + one metric fetch per chunk instead of per
    round.

    ``participation``/``buffer`` are the repro.fed hooks
    (``launch.driver.round_hook_kwargs``, DESIGN §9): the cohort mask is
    evaluated IN THE SCAN BODY as a pure function of the absolute round
    index and consumed inside the round's sketch shard_map; a buffered run
    carries the staleness ring (``init_mesh_async_state``) in place of the
    bare opt state, donated like every other carry leaf.  An all-ones mask
    and a delay=0 buffer are pinned bitwise to the hookless scan.
    ``faults``/``sentinel`` (DESIGN.md §10) inject and contain payload
    faults; their chunk history grows the per-round ``n_dropped``/
    ``n_rejected``/``diverged`` counters next to the loss (disabled hooks
    leave the scan program -- and the pinned trajectories -- untouched).

    Signature of the returned fn:
        ``(params, opt_state, data_state, key_data, t0) ->
           (params, opt_state, data_state, key_data, hist)``
    ``t0`` is a traced scalar so successive chunks of one length share one
    executable; ``hist["loss"]`` is the chunk's on-device loss history.
    Returns ``(chunk_fn, pspecs)``.
    """
    core, pspecs = _make_round_core(model_cfg, safl_cfg, mesh, topology,
                                    participation=participation,
                                    buffer=buffer, faults=faults,
                                    sentinel=sentinel, telemetry=telemetry,
                                    microbatch=microbatch, codec=codec)

    def chunk(params, opt_state, data_state, key_data, t0):
        def body(carry, t):
            params, opt_state, dstate, kd = carry
            dstate, batch = sampler.sample(dstate, t)
            base = jax.random.wrap_key_data(kd)
            kw, _ = round_hook_kwargs(t, base, None, participation,
                                      buffer is not None, faults)
            rk = jax.random.fold_in(base, t)
            params, opt_state, m = core(params, opt_state, batch, rk, **kw)
            # fault/sentinel cores return the full metrics dict; everything
            # else keeps the bare-loss history (static distinction)
            return ((params, opt_state, dstate, kd),
                    m if isinstance(m, dict) else {"loss": m})

        (params, opt_state, data_state, key_data), hist = jax.lax.scan(
            body, (params, opt_state, data_state, key_data),
            t0 + jnp.arange(num_rounds, dtype=jnp.int32))
        return params, opt_state, data_state, key_data, hist

    return (jax.jit(chunk, donate_argnums=(0, 1, 2, 3) if donate else ()),
            pspecs)


def make_fedopt_scan_fn(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh,
                        topology: str = "cross_device", *, sampler,
                        num_rounds: int, donate: bool = True,
                        participation=None, buffer=None, faults=None,
                        sentinel=None, telemetry=None, microbatch=None,
                        codec=None):
    """Scanned uncompressed FedOPT mesh rounds (``sketch.kind == "none"``:
    the raw-delta O(d) all-reduce inside the same scan layout)."""
    return make_safl_scan_fn(model_cfg, _fedopt_cfg(safl_cfg), mesh,
                             topology, sampler=sampler,
                             num_rounds=num_rounds, donate=donate,
                             participation=participation, buffer=buffer,
                             faults=faults, sentinel=sentinel,
                             telemetry=telemetry, microbatch=microbatch,
                             codec=codec)


def run_mesh_scan(model_cfg: ModelConfig, safl_cfg: SAFLConfig, mesh, sampler,
                  params, opt_state, *, rounds: int, key,
                  topology: str = "cross_device", chunk_size: int = 0,
                  start_round: int = 0, donate: bool = True, on_chunk=None,
                  participation=None, buffer=None, faults=None,
                  sentinel=None, telemetry=None, stream=None,
                  microbatch=None, codec=None):
    """Run ``rounds`` mesh rounds in scanned chunks (the multi-pod analogue
    of ``launch.driver.run_scan``).

    ``chunk_size`` bounds rounds per dispatch (0 = all in one); metrics
    cross to the host once per chunk and ``on_chunk(t_done, params,
    opt_state, chunk_hist)`` runs between chunks.  ``start_round`` resumes a
    ``(t, key)`` checkpoint cursor mid-trajectory (every per-round stream --
    data, cohorts, delays, sketch operators -- is a pure function of the
    absolute round index under ``key``).

    **Hook contract** (the full set, with each hook's pin class -- see
    DESIGN.md appendix "Pinning methodology" for the taxonomy):

    * ``participation=`` (sampling policy, DESIGN §9): the per-round cohort
      mask is evaluated in the scan body and consumed inside the round's
      sketch shard_map.  ``None`` is bitwise-neutral; an all-ones 0/1 mask
      reproduces the hookless trajectory bitwise
      (tests/test_mesh_scan.py).
    * ``buffer=`` (an ``fed.async_buffer.AsyncConfig``): ``opt_state`` must
      then be the ``init_mesh_async_state`` dict (the staleness ring rides
      the donated scan carry).  A delay=0 buffer is bitwise the hookless
      scan; nonzero delays are their own program family.
    * ``faults=`` / ``sentinel=`` (DESIGN.md §10): fault-injection /
      payload-sentinel hooks; their history carries ``n_dropped``/
      ``n_rejected``/``diverged`` counters next to the loss, which is what
      the rollback supervisor (``launch.supervisor``) watches.  Disabled
      (``None``) they are bitwise-neutral; enabled they form their own
      family (extra scan outputs shift XLA fusion).
    * ``telemetry=`` (static ``repro.obs.Telemetry``, DESIGN §11): adds the
      in-graph probe keys to the history; its own family when enabled,
      bitwise-neutral when ``None``.
    * ``stream=`` (a ``repro.obs.shards.ShardWriter``): switches to
      streamed per-chunk JSONL shards + wall-time span events and skips the
      in-memory accumulation, exactly as in ``launch.driver.run_scan`` (the
      returned ``history`` is then ``{}``).  Host-side only -- never
      changes the compiled round program.
    * ``microbatch=`` (static int, DESIGN §12): streams each shard's sketch
      stage over chunks of that many client rows (plain sketched cores
      only -- combining with buffer/faults/sentinel/telemetry raises).
      ``None`` or >= the shard-local cohort keeps the materialized program
      bitwise-pinned; a streaming value is its own family, allclose to the
      materialized path.
    * ``codec=`` (static ``fed.codec.CodecConfig``, DESIGN.md §13):
      quantizes each shard's sketch partial-sum before the one psum (plain
      sketched cores only; requires ``error_feedback=False`` -- per-client
      EF does not exist at shard granularity).  ``None`` is
      bitwise-neutral; an enabled codec is its own family and its history
      reports the MEASURED ``uplink_bits``.

    Returns ``(params, opt_state, history)`` with host-side
    ``(rounds - start_round,)`` arrays (key set:
    ``launch.driver.HISTORY_KEYS``)."""
    chunk_size = int(chunk_size) or int(rounds)
    data_state = sampler.init_state()
    # host copy of the (invariant) base key: the donated key carry comes
    # back as a pass-through output of its own donated buffer, so each chunk
    # gets a fresh device copy instead of rethreading a deleted array
    kd_host = np.asarray(jax.random.key_data(key))
    compiled: dict[int, Callable] = {}
    hists = []
    t = int(start_round)
    while t < rounds:
        n = min(chunk_size, rounds - t)
        fresh = n not in compiled
        if fresh:               # tail chunk of a different length re-jits
            compiled[n], _ = make_safl_scan_fn(
                model_cfg, safl_cfg, mesh, topology, sampler=sampler,
                num_rounds=n, donate=donate, participation=participation,
                buffer=buffer, faults=faults, sentinel=sentinel,
                telemetry=telemetry, microbatch=microbatch, codec=codec)
        t_wall = time.perf_counter()
        params, opt_state, data_state, _, hist = compiled[n](
            params, opt_state, data_state, jnp.asarray(kd_host),
            jnp.asarray(t, jnp.int32))
        if stream is not None:
            from repro.obs.shards import host_fetch
            hist = host_fetch(hist)            # async copy, ONE fetch
            dt = time.perf_counter() - t_wall
            stream.write_chunk(t, hist)
            stream.write_span(t, t + n, dt, compile=fresh)
        else:
            hist = jax.tree.map(np.asarray, hist)  # ONE fetch per chunk
            hists.append(hist)
        t += n
        if on_chunk is not None:
            on_chunk(t, params, opt_state, hist)
    if not hists:   # streamed, or resumed at start_round == rounds
        return params, opt_state, {}
    history = jax.tree.map(lambda *xs: np.concatenate(xs), *hists)
    return params, opt_state, history


def run_mesh_host_loop(step, sampler, params, opt_state, *, rounds: int, key,
                       start_round: int = 0, donate: bool = True,
                       participation=None, buffer=None, faults=None,
                       sentinel=None):
    """One-jitted-dispatch-per-round mesh reference with the scanned
    driver's EXACT key/batch sequence: round t consumes
    ``key_data(fold_in(key, t))`` and ``sampler.sample(state, t)``.
    ``step`` is the per-round fn from ``make_safl_train_step`` /
    ``make_fedopt_train_step``.  benchmarks/run.py times this against
    ``run_mesh_scan`` (mesh/<algo> vs mesh/<algo>_scan); the trajectories
    agree bitwise.

    With the repro.fed hooks, build ``step`` with the SAME
    ``participation=``/``buffer=``/``faults=``/``sentinel=`` and pass them
    here too: the hooked step takes ``(params, state, batch, base_key_data,
    t)`` and re-derives the round key / cohort mask / fault spec itself, so
    this loop feeds it the base key and the absolute round index instead of
    the folded round key.  Fault/sentinel steps emit a metrics dict per
    round; the history stacks every key."""
    data_state = sampler.init_state()
    sample = jax.jit(sampler.sample)
    jstep = jax.jit(step, donate_argnums=(0, 1) if donate else ())
    hooked = (participation is not None or buffer is not None
              or faults is not None or sentinel is not None)
    kd_base = np.asarray(jax.random.key_data(key))
    hists = []
    for t in range(int(start_round), rounds):
        data_state, batch = sample(data_state, jnp.asarray(t, jnp.int32))
        if hooked:
            params, opt_state, m = jstep(
                params, opt_state, batch, jnp.asarray(kd_base),
                jnp.asarray(t, jnp.int32))
        else:
            kd = jax.random.key_data(jax.random.fold_in(key, t))
            params, opt_state, m = jstep(params, opt_state, batch, kd)
        if not isinstance(m, dict):
            m = {"loss": m}
        hists.append(jax.tree.map(np.asarray, m))  # blocks every round
    return params, opt_state, jax.tree.map(lambda *xs: np.stack(xs), *hists)


def make_prefill_step(model_cfg: ModelConfig):
    def step(params, batch):
        h, _ = forward(model_cfg, params, batch, remat=False)
        head = (params["embed"].T if model_cfg.tie_embeddings
                else params["lm_head"])
        return h[:, -1] @ head                      # (B, V) last-token logits
    return step


def make_serve_step(model_cfg: ModelConfig):
    def step(params, cache, tokens, pos):
        return decode_step(model_cfg, params, cache, tokens, pos)
    return step


# ---------------------------------------------------------------------------
# sharding spec helpers for jit in_shardings
# ---------------------------------------------------------------------------

def batch_pspecs(batch_tree, mesh, topology: str = "cross_device") -> Pytree:
    """Train-batch specs: (G, K, mb, ...).  cross_device shards G over
    (pod, data); cross_silo shards G over pod and mb over data."""
    caxes = client_axes_of(mesh, topology)
    lead = caxes if caxes else None
    if topology == "cross_silo":
        inner = "data" if "data" in mesh.axis_names else None
        return jax.tree.map(
            lambda x: P(*((lead, None, inner) + (None,) * (x.ndim - 3))),
            batch_tree)
    if topology == "cross_device_dp":
        return jax.tree.map(
            lambda x: P(*((lead, None, "model") + (None,) * (x.ndim - 3))),
            batch_tree)
    return jax.tree.map(
        lambda x: P(*((lead,) + (None,) * (x.ndim - 1))), batch_tree)


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def infer_batch_pspecs(batch_tree, data_axes, mesh=None) -> Pytree:
    """Inference batch: leading batch dim over (pod, data); left replicated
    when the batch does not divide the axes (e.g. long_500k with B=1)."""
    def spec(x):
        axes = data_axes
        if mesh is not None and x.shape[0] % _axes_size(mesh, data_axes):
            axes = None
        return P(*((axes,) + (None,) * (x.ndim - 1)))
    return jax.tree.map(spec, batch_tree)


def cache_pspecs(cache_tree, data_axes, mesh=None) -> Pytree:
    """KV caches are sequence-sharded over the model axis (flash-decoding
    style partial softmax via GSPMD); SSM state shards d_inner.  The batch
    dim falls back to replicated when it does not divide the data axes."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = []
    for path, leaf in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        nd = leaf.ndim
        baxes = data_axes
        if mesh is not None and leaf.shape[1] % _axes_size(mesh, data_axes):
            baxes = None
        if name in ("k", "v", "xk", "xv"):       # (nb, B, S, Hk, hd)
            sp = (None, baxes, "model", None, None)
        elif name in ("ckv", "kpe"):             # (nb, B, S, r)
            sp = (None, baxes, "model", None)
        elif name == "h":                        # (nb, B, di, ds)
            sp = (None, baxes, "model", None)
        elif name == "conv":                     # (nb, B, kw-1, di)
            sp = (None, baxes, None, "model")
        else:
            sp = (None,) * nd
        if mesh is not None:
            # drop any axis a dim cannot divide (e.g. whisper's 1500-frame
            # cross cache on a 16-way model axis)
            fixed = []
            for dim, e in zip(leaf.shape, sp[:nd]):
                if e is None:
                    fixed.append(None)
                    continue
                axes = e if isinstance(e, tuple) else (e,)
                fixed.append(e if dim % _axes_size(mesh, axes) == 0 else None)
            sp = tuple(fixed)
        specs.append(P(*sp[:nd]))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_pspecs(server: AdaConfig, pspecs) -> dict:
    out = {"step": P()}
    for k in ("m", "v", "vhat"):
        if (server.name in ("amsgrad", "adam", "sgdm") and k == "m") or \
           (server.name in ("amsgrad", "adam", "adagrad") and k == "v") or \
           (server.name == "amsgrad" and k == "vhat"):
            out[k] = pspecs
    return out


def to_shardings(mesh, pspec_tree):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# runnable single-host trainer (examples / integration tests use this)
# ---------------------------------------------------------------------------

def train_loop(model_cfg: ModelConfig, safl_cfg: SAFLConfig, data,
               rounds: int, *, batch_per_client: int = 8, log_every: int = 10,
               seed: int = 0, scan: bool = True, chunk_size: int = 0):
    """CPU-scale SAFL training on real (synthetic-dataset) batches.

    When ``data`` supports device-side sampling (``device_sampler``) the
    whole run executes as scanned on-device chunks with donated carries
    (launch/driver.py, DESIGN.md §6); metrics come back once per chunk.
    Other datasets fall back to the host-driven loop (still with donated
    params/opt buffers, so no per-round copy)."""
    from repro.core.packed import make_packing_plan
    from repro.core.safl import init_safl, safl_round
    key = jax.random.key(seed)
    from repro.models.model import init_params
    params = init_params(model_cfg, key)
    opt = init_safl(safl_cfg, params)
    loss = lambda p, b: loss_fn(model_cfg, p, b)
    # static sketch layout built ONCE, outside any trace
    plan = make_packing_plan(safl_cfg.sketch, params)
    round_fn = functools.partial(safl_round, safl_cfg, loss, plan=plan)

    if scan and hasattr(data, "device_sampler"):
        from repro.launch.driver import run_scan
        sampler = data.device_sampler(batch_per_client, safl_cfg.local_steps)

        def on_chunk(t_done, _params, _opt, hist):
            if log_every:
                print(f"round {t_done - 1:4d}  loss {hist['loss'][-1]:.4f}")

        params, opt, hist = run_scan(
            round_fn, sampler, params, opt, rounds=rounds, key=key,
            chunk_size=chunk_size or (log_every or rounds),
            on_chunk=on_chunk)
        return params, opt, [float(x) for x in hist["loss"]]

    round_jit = jax.jit(round_fn, donate_argnums=(0, 1))
    history = []
    for t in range(rounds):
        batch = data.round_batch(batch_per_client, safl_cfg.local_steps, t)
        params, opt, m = round_jit(params, opt, batch, jax.random.fold_in(key, t))
        history.append(float(m["loss"]))
        if log_every and (t % log_every == 0 or t == rounds - 1):
            print(f"round {t:4d}  loss {history[-1]:.4f}")
    return params, opt, history


def _main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--sketch", default="countsketch")
    ap.add_argument("--ratio", type=float, default=0.1)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data import BigramLMData, LMDataConfig
    cfg = get_config(args.arch, smoke=args.smoke)
    safl = SAFLConfig(
        sketch=SketchConfig(kind=args.sketch, ratio=args.ratio),
        server=AdaConfig(name="amsgrad", lr=0.003),
        client_lr=0.05, local_steps=args.local_steps)
    data = BigramLMData(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, num_clients=args.clients))
    train_loop(cfg, safl, data, args.rounds)


if __name__ == "__main__":
    _main()


def flat_tp_pspecs(pspecs, params_abs=None) -> Pytree:
    """Beyond-paper serving layout: fold the data axis into the model axis
    (256-way pure TP), sharding every weight's CONTRACTING (input) dim.

    v2 after a refuted iteration (EXPERIMENTS §Perf H3): sharding output/head
    dims conflicts with the sequence-sharded KV cache and makes GSPMD
    all-gather the cache (1.8 TB/step observed).  Contracting-dim sharding
    keeps weights fully resident AND the cache sequence-sharded; every
    matmul just all-reduces its (tiny, batch x features) decode activation.
    MoE experts stay expert-sharded (resident) with token all-to-all."""
    _W = {"wq", "wk", "wv", "wo", "wi", "wg", "w_dq", "w_uq", "w_dkv",
          "w_kr", "w_uk", "w_uv", "lm_head", "mtp_head", "router",
          "x_proj", "dt_proj", "out_proj", "wx", "wz"}

    def conv(path, p):
        name = str(getattr(path[-1], "key", path[-1]))
        parent = str(getattr(path[-2], "key", path[-2])) if len(path) > 1 \
            else ""
        nd = len(p)
        tp = ("data", "model")
        if name in ("wi", "wg", "wo") and parent in ("moe",) and nd >= 3:
            # stacked experts (nb, E, in, out): shard E (resident experts)
            lead = nd - 3
            return P(*((None,) * lead + (tp, None, None)))
        if name == "embed":
            return P(tp, None)
        if name in _W and nd >= 2:
            # shard the contracting dim (second-to-last) -> output psum
            return P(*((None,) * (nd - 2) + (tp, None)))
        return P(*((None,) * nd))
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    return jax.tree_util.tree_unflatten(
        treedef, [conv(path, p) for path, p in flat])


def flat_tp_cache_pspecs(cache_tree, mesh=None) -> Pytree:
    """Cache layout for flat-TP serving: sequence dim over (data, model),
    batch replicated."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = []
    tp = ("data", "model")
    for path, leaf in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        nd = leaf.ndim
        if name in ("k", "v", "xk", "xv"):
            sp = (None, None, tp, None, None)
        elif name in ("ckv", "kpe"):
            sp = (None, None, tp, None)
        elif name == "h":
            sp = (None, None, tp, None)
        elif name == "conv":
            sp = (None, None, None, tp)
        else:
            sp = (None,) * nd
        if mesh is not None:
            fixed = []
            for dim, e in zip(leaf.shape, sp[:nd]):
                if e is None:
                    fixed.append(None)
                    continue
                axes = e if isinstance(e, tuple) else (e,)
                fixed.append(e if dim % _axes_size(mesh, axes) == 0 else None)
            sp = tuple(fixed)
        specs.append(P(*sp[:nd]))
    return jax.tree_util.tree_unflatten(treedef, specs)
