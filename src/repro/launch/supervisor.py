"""Checkpoint-rollback supervisor: host-side retry loop over the scanned
drivers (DESIGN.md §10).

The sentinels (``fed.robust``) contain per-client faults INSIDE a round;
this layer contains whole-run divergence ACROSS rounds.  It wraps any
chunked launcher (``launch.driver.run_scan`` or ``launch.train
.run_mesh_scan``) and, after every chunk, inspects the chunk's metric
history AND the end-of-chunk params: a non-finite loss, a loss above the
configured divergence threshold, a fired ``diverged`` sentinel flag, or
non-finite params marks the chunk BAD.  On a bad chunk the supervisor

1. rolls back to a good ``(t, key)`` cursor -- the PR-4 resume path: every
   per-round stream (data, cohorts, delays, faults, sketch operators) is a
   pure function of the absolute round index under the run key, so
   re-launching from a snapshot replays the uninterrupted trajectory;
2. re-runs from there with a REKEYED run key (``fold_in(base_key,
   _REKEY_TAG + retry)``), which redraws every transient fault stream --
   the retry can escape a bad draw (``fed.faults`` default keying), while
   ``persistent=True`` faults re-fire and exhaust the retry budget, which
   is exactly the semantics a deterministic poison should have;
3. sleeps an exponential backoff between retries and gives up with a
   ``SupervisorError`` (carrying the full recovery log) after
   ``max_retries`` total retries.

**Detection lag.**  A round's loss is measured BEFORE its own server
update, so a chunk whose last round diverges can validate clean while its
end-of-chunk params are already poisoned -- and a rollback to that cursor
would resume inside the blast radius.  Two defenses: the end-of-chunk
params are finite-checked on the host copy the snapshot takes anyway, and
the supervisor keeps a bounded STACK of good snapshots -- when a resume
from some cursor faults again, that snapshot is distrusted and the stack
pops to the previous one (deepening rollback), truncating the stitched
history to match.  The stack bottom is the run's initial state, so the
worst case is a clean restart, still bounded by ``max_retries``.

Snapshots are HOST copies (``np.asarray``): both drivers donate their
device carries, so a device-side reference would be invalidated by the
very launch it is meant to guard.  The returned history is the stitched
concatenation of the good chunks that STAND at exit, plus a
``recovery_log`` of dicts ``{retry, t_fault, t_resume, reason}``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

Pytree = Any

# decorrelates retry keys from the per-round fold_in(key, t) chain (round
# indices are small ints; retry counts are added to this tag)
_REKEY_TAG = 0x5AFE


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    """``divergence=0`` treats only non-finite signals (and fired sentinel
    flags) as faults; a positive threshold also catches finite loss
    blow-ups.  ``backoff_s`` is the base of the exponential between-retry
    sleep -- keep it 0 in tests, nonzero when retries contend for real
    hardware.  ``keep_snapshots`` bounds rollback memory: the initial state
    plus the most recent K-1 good cursors are retained."""
    max_retries: int = 3
    backoff_s: float = 0.0
    divergence: float = 0.0
    keep_snapshots: int = 8

    def __post_init__(self):
        assert self.max_retries >= 0
        assert self.backoff_s >= 0.0
        assert self.divergence >= 0.0
        assert self.keep_snapshots >= 2


class SupervisorError(RuntimeError):
    """Raised when the retry budget is exhausted; ``.log`` holds the full
    recovery log (every rollback attempted, with reasons)."""

    def __init__(self, msg: str, log: list):
        super().__init__(msg)
        self.log = log


class _ChunkFault(Exception):
    def __init__(self, t_done: int, reason: str):
        super().__init__(reason)
        self.t_done = t_done
        self.reason = reason


def chunk_is_bad(hist: dict, divergence: float = 0.0):
    """Host-side chunk verdict: ``(bad, reason)`` from a chunk's stacked
    metric history (the same signals the in-graph ``diverged`` sentinel
    flags, evaluated on the host where we can actually stop the run)."""
    loss = np.asarray(hist.get("loss", np.zeros((0,))))
    finite = np.isfinite(loss)
    if not finite.all():
        i = int(np.argmin(finite))
        return True, f"non-finite loss at chunk offset {i}"
    if divergence > 0.0 and (loss > divergence).any():
        i = int(np.argmax(loss > divergence))
        return True, (f"loss {float(loss[i]):.4g} above divergence "
                      f"threshold {divergence:g} at chunk offset {i}")
    flags = np.asarray(hist.get("diverged", np.zeros((0,))))
    if flags.size and (flags > 0).any():
        i = int(np.argmax(flags > 0))
        return True, f"divergence sentinel fired at chunk offset {i}"
    return False, ""


def _host(tree: Pytree) -> Pytree:
    return jax.tree.map(np.asarray, tree)


def _finite_tree(tree: Pytree) -> bool:
    return all(np.isfinite(x).all()
               for x in jax.tree.leaves(tree)
               if np.issubdtype(np.asarray(x).dtype, np.floating))


def run_supervised(launch: Callable, params: Pytree, state: Pytree, *,
                   rounds: int, key, config: SupervisorConfig | None = None,
                   on_chunk=None, ckpt_path: str | None = None,
                   start_round: int = 0, stream=None):
    """Supervise a chunked driver run with rollback-and-rekey retries.

    ``launch(params, state, *, key, start_round, on_chunk) ->
    (params, state, hist)`` adapts the underlying driver; e.g. for the
    single-host scan::

        launch = lambda p, s, *, key, start_round, on_chunk: run_scan(
            round_fn, sampler, p, s, rounds=R, key=key, chunk_size=C,
            start_round=start_round, on_chunk=on_chunk, faults=faults)

    (``run_mesh_scan`` adapts identically -- both drivers share the
    ``start_round`` cursor and per-chunk ``on_chunk`` contract this loop
    needs).  The supervisor owns the driver's ``on_chunk`` slot for fault
    detection and snapshotting; the caller's ``on_chunk(t_done, params,
    state, hist)`` still runs for every chunk that validates good.
    ``ckpt_path`` persists each good ``(t, key)`` cursor via
    ``checkpoint.save_checkpoint`` (atomic write), the same layout
    examples/train_lm.py resumes from.  ``start_round`` seeds the root
    snapshot for a run resumed from a checkpoint cursor: rollbacks bottom
    out there, never before the restored state's round.

    ``stream`` (a ``repro.obs.shards.ShardWriter``, normally the SAME one
    handed to the underlying driver) makes the supervisor emit each
    rollback as a structured ``recovery`` event into the run's event log --
    retry count, fault/resume cursors, rollback depth, rekey tag -- and
    skip its own in-memory history stitching (the shard files are the
    record; a retried span re-emits its rounds in new shards and readers
    resolve duplicate ``t`` last-wins, with the recovery events marking
    where that happened).  The returned ``history`` is then ``{}``.

    Returns ``(params, state, history, recovery_log)``.
    """
    config = config or SupervisorConfig()
    base_key = key
    cur_key = key
    snaps = [{"t": int(start_round), "params": _host(params),
              "state": _host(state)}]
    hists: list = []      # (t_start, t_end, hist) of good chunks that stand
    log: list = []
    retries = 0
    last_resume = None    # cursor of the most recent rollback, if any

    def sup_on_chunk(t_done, p, s, hist):
        bad, reason = chunk_is_bad(hist, config.divergence)
        if bad:
            raise _ChunkFault(t_done, reason)
        hp, hs = _host(p), _host(s)
        if not _finite_tree(hp):
            # detection lag: the last round's loss predates its own poisoned
            # server update -- never snapshot a non-finite cursor
            raise _ChunkFault(t_done, "non-finite params at chunk end")
        t_start = snaps[-1]["t"]
        snaps.append({"t": t_done, "params": hp, "state": hs})
        if len(snaps) > config.keep_snapshots:
            del snaps[1]          # keep the initial state as the root
        if stream is None:        # streamed runs: the shards are the record
            hists.append((t_start, t_done, hist))
        if ckpt_path is not None:
            from repro.checkpoint import save_checkpoint
            save_checkpoint(
                ckpt_path,
                {"params": hp, "opt": hs,
                 "cursor": {"t": np.asarray(t_done),
                            "key": np.asarray(
                                jax.random.key_data(cur_key))}},
                step=t_done)
        if on_chunk is not None:
            on_chunk(t_done, p, s, hist)

    while True:
        top = snaps[-1]
        try:
            p_out, s_out, _ = launch(top["params"], top["state"],
                                     key=cur_key, start_round=top["t"],
                                     on_chunk=sup_on_chunk)
            if not _finite_tree(_host(p_out)):
                raise _ChunkFault(rounds, "non-finite final params")
        except _ChunkFault as f:
            retries += 1
            if retries > config.max_retries:
                raise SupervisorError(
                    f"retry budget exhausted ({config.max_retries}) after "
                    f"fault at round < {f.t_done}: {f.reason}", log)
            if config.backoff_s > 0.0:
                time.sleep(config.backoff_s * 2.0 ** (retries - 1))
            if snaps[-1]["t"] == last_resume and len(snaps) > 1:
                # resuming from this cursor already faulted once: the
                # snapshot itself may sit inside the blast radius -- deepen
                snaps.pop()
            t_res = snaps[-1]["t"]
            hists[:] = [h for h in hists if h[1] <= t_res]
            last_resume = t_res
            cur_key = jax.random.fold_in(base_key, _REKEY_TAG + retries)
            log.append({"retry": retries, "t_fault": int(f.t_done),
                        "t_resume": int(t_res), "reason": f.reason})
            if stream is not None:
                stream.write_event(
                    "recovery", retry=retries, t_fault=int(f.t_done),
                    t_resume=int(t_res),
                    depth=int(f.t_done) - int(t_res), reason=f.reason,
                    rekey=_REKEY_TAG + retries)
            continue
        history = (jax.tree.map(lambda *xs: np.concatenate(xs),
                                *[h for _, _, h in hists])
                   if hists else {})
        return p_out, s_out, history, log


def format_recovery_log(log: list) -> str:
    """Human-readable recovery report (examples/train_lm.py prints this)."""
    if not log:
        return "supervisor: clean run, no rollbacks"
    lines = [f"supervisor: {len(log)} rollback(s)"]
    for e in log:
        lines.append(
            f"  retry {e['retry']}: fault before round {e['t_fault']} "
            f"({e['reason']}); resumed from round {e['t_resume']} with "
            f"rekeyed streams")
    return "\n".join(lines)
