"""Roofline-term extraction from compiled dry-run artifacts (DESIGN §6).

Hardware model: TPU v5e -- 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis()`` of a GSPMD-partitioned module reports **per-device**
FLOPs/bytes, and the partitioned HLO text carries **per-device** shapes, so:

    compute_s    = flops_per_device / PEAK_FLOPS        (= global/(chips*peak))
    memory_s     = bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW

collective bytes are parsed from the compiled HLO: the summed output sizes of
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
ops (output size ~ bytes a device must move for ring/bidirectional
implementations; we do not model link multiplicity -- constants are recorded
so readers can rescale).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Optional

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  "%all-gather.3 = bf16[8,128]{1,0} all-gather(...)"
#       "... = (f32[4,8]{...}, f32[4,8]{...}) tuple ... all-reduce(...)"
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9_]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(" + "|".join(k.replace("-", r"\-") for k in _COLL_KINDS) + r")")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device bytes moved by each collective kind (+ op counts)."""
    out = {k: 0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(type_str)
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLL_KINDS)
    out["counts"] = counts  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float            # 6*N*D (global, per optimizer step)
    useful_flops_ratio: float     # model_flops / (flops_per_device * chips)
    memory_report: str
    bytes_per_device_hbm: Optional[float] = None  # from memory_analysis
    note: str = ""

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["coll_breakdown"] = {k: v for k, v in self.coll_breakdown.items()}
        return d


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float, note: str = "",
            analytic_mem_bytes: float | None = None) -> RooflineReport:
    """Roofline terms from the trip-count-weighted static HLO profile
    (hlo_costs.py).  Raw XLA cost_analysis numbers (which count scan bodies
    once) are preserved in the note for cross-checking."""
    from repro.launch.hlo_costs import analyze_hlo_text
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    hc = analyze_hlo_text(hlo)
    flops = hc.flops                      # per-device, trip-weighted
    byts = max(hc.bytes, raw_bytes)       # HBM proxy, trip-weighted
    coll = dict(hc.coll_bytes)
    coll["total"] = hc.coll_total
    coll["counts"] = hc.coll_count
    note = (note + f" raw_cost_analysis(flops={raw_flops:.3e},"
            f" bytes={raw_bytes:.3e})")
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll["total"] / ICI_BW
    if analytic_mem_bytes is not None:
        memory_s = analytic_mem_bytes / HBM_BW
        note += f" hlo_bytes_proxy={byts:.3e}"
        byts = analytic_mem_bytes
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    mem_rep = ""
    hbm_bytes = None
    try:
        ma = compiled.memory_analysis()
        mem_rep = str(ma)
        hbm_bytes = float(
            getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            + getattr(ma, "temp_size_in_bytes", 0))
    except Exception as e:  # pragma: no cover
        mem_rep = f"memory_analysis unavailable: {e}"

    useful = model_flops / max(flops * chips, 1.0)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        coll_bytes_per_device=float(coll["total"]), coll_breakdown=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops,
        useful_flops_ratio=useful, memory_report=mem_rep,
        bytes_per_device_hbm=hbm_bytes, note=note)


def model_flops_for(cfg, shape_info, *, local_steps: int = 1) -> float:
    """6*N*D for training (N = active params, D = global tokens x K),
    2*N*D for inference."""
    from repro.models.model import count_params_analytic
    n_active = count_params_analytic(cfg, active_only=True)
    if shape_info.kind == "train":
        tokens = shape_info.global_batch * shape_info.seq_len * local_steps
        return 6.0 * n_active * tokens
    if shape_info.kind == "prefill":
        tokens = shape_info.global_batch * shape_info.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape_info.global_batch


def format_row(r: RooflineReport) -> str:
    return (f"{r.arch:24s} {r.shape:12s} {r.mesh:10s} "
            f"comp={r.compute_s:9.3e}s mem={r.memory_s:9.3e}s "
            f"coll={r.collective_s:9.3e}s dom={r.dominant:10s} "
            f"useful={r.useful_flops_ratio:6.3f}")


# ---------------------------------------------------------------------------
# Analytic HBM-traffic estimator (DESIGN §6).
#
# The HLO byte proxy counts every op's operands at HLO granularity, which on
# the (barely-fused) CPU backend massively over-counts what a TPU keeps in
# VMEM inside fused loops.  The roofline memory term therefore uses this
# documented analytic estimate; the HLO proxy is retained in the report for
# comparison.
#
#   train:   weights read twice (fwd+bwd) + grad write + moments r/w
#            + activation traffic ~ c_act * tokens * d_model * layers
#   prefill: weights read once + activation traffic
#   decode:  active weights read once per token + KV/SSM cache read + small
# All divided by the chip count (weights sharded; tokens sharded).
# ---------------------------------------------------------------------------

C_ACT_TRAIN = 16.0   # bytes-touch factor per token-dim-layer (remat incl.)
C_ACT_FWD = 6.0


def analytic_memory_bytes(cfg, shape_info, chips: int, *,
                          moment_bytes: int = 4,
                          local_steps: int = 1) -> float:
    from repro.models.model import count_params_analytic
    n_total = count_params_analytic(cfg)
    n_active = count_params_analytic(cfg, active_only=True)
    wbytes = jnp_dtype_bytes(cfg.dtype)
    d, L = cfg.d_model, cfg.num_layers

    if shape_info.kind == "train":
        tokens = shape_info.global_batch * shape_info.seq_len * local_steps
        weights = n_total * wbytes * 3.0            # fwd read + bwd read + delta write
        moments = n_total * moment_bytes * 3.0 * 2  # m, v, vhat read+write
        acts = C_ACT_TRAIN * tokens * d * L * wbytes
        return (weights + moments + acts) / chips
    if shape_info.kind == "prefill":
        tokens = shape_info.global_batch * shape_info.seq_len
        return (n_total * wbytes + C_ACT_FWD * tokens * d * L * wbytes) / chips
    # decode: one step
    cache = decode_cache_bytes(cfg, shape_info)
    return (n_active * wbytes + cache) / chips


def decode_cache_bytes(cfg, shape_info) -> float:
    """Total KV/SSM cache bytes read per decode step (global)."""
    B, S = shape_info.global_batch, shape_info.seq_len
    wb = jnp_dtype_bytes(cfg.dtype)
    total = 0.0
    for mixer, _ in cfg.layer_kinds():
        if mixer == "attn":
            if cfg.mla:
                total += B * S * (cfg.kv_lora_rank + cfg.qk_rope_dim) * wb
            else:
                s_eff = min(S, cfg.sliding_window) if cfg.sliding_window else S
                total += B * s_eff * cfg.num_kv_heads * cfg.hd * 2 * wb
        else:
            total += B * cfg.d_inner * cfg.ssm_state * 4.0
    if cfg.encoder_layers:
        total += cfg.encoder_layers * shape_info.global_batch * \
            cfg.encoder_seq * cfg.num_kv_heads * cfg.hd * 2 * wb
    return total


def jnp_dtype_bytes(dt) -> int:
    import jax.numpy as jnp
    return jnp.dtype(dt).itemsize
