"""Static HLO profiler with while-loop trip-count weighting.

XLA's ``compiled.cost_analysis()`` counts each computation ONCE, so a
lax.scan over 64 layers under-counts FLOPs/bytes/collective-bytes by 64x.
This module parses the compiled (post-SPMD, per-device shapes) HLO text,
builds the computation call graph (while bodies, calls, fusions), weights
every computation by the product of enclosing ``known_trip_count``s, and
accumulates:

* matmul FLOPs (dot ops: 2 * prod(out) * prod(contracting dims)),
* an HBM-traffic proxy (operand + output bytes of schedulable ops at fusion
  granularity),
* collective bytes per kind (all-reduce / all-gather / reduce-scatter /
  all-to-all / collective-permute), the §Roofline collective numerator.

This is the "profile" the perf loop reads (DESIGN §6): no real TPU timing
exists in this container, so we reason from trip-weighted static costs.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->.*{")
_OP_LINE = re.compile(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r'known_trip_count[^0-9]*([0-9]+)')
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_OPERANDS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_OPND_NAME = re.compile(r"%([\w\.\-]+)")


def _first_shape(type_str: str):
    m = _SHAPE.search(type_str)
    if not m:
        return None, 0
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return None, 0
    shape = [int(d) for d in dims.split(",")] if dims else []
    return shape, _DTYPE_BYTES[dt]


def _all_shapes_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in (dims.split(",") if dims else []):
            n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLL_KINDS})
    coll_count: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in COLL_KINDS})

    @property
    def coll_total(self) -> float:
        return sum(self.coll_bytes.values())


def analyze_hlo_text(text: str) -> HloCosts:
    # ---- pass 1: split into computations, collect op lines ----
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        if (line and not line.startswith(" ") and "->" in line
                and line.rstrip().endswith("{")
                and (line.startswith("%") or line.startswith("ENTRY"))):
            tok = line.split()[1] if line.startswith("ENTRY") else line.split()[0]
            cur = tok.lstrip("%")
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)

    # name -> full type string (for operand shape lookup)
    types: dict[str, str] = {}
    for name, lines in comps.items():
        for ln in lines:
            m = _OP_LINE.match(ln)
            if m:
                types[m.group(1)] = m.group(2)
        # parameters keep their type in the header; approximate via op refs

    # ---- pass 2: call graph with multipliers ----
    children: dict[str, list[tuple[str, float]]] = defaultdict(list)
    fusion_comps: set[str] = set()
    for name, lines in comps.items():
        for ln in lines:
            m = _OP_LINE.match(ln)
            if not m:
                continue
            rhs = m.group(2)
            if " while(" in rhs or rhs.startswith("while("):
                trips = 1.0
                tm = _TRIP.search(rhs)
                if tm:
                    trips = float(tm.group(1))
                bm = re.search(r"body=%?([\w\.\-]+)", rhs)
                cm = _COND.search(rhs)
                if bm:
                    children[name].append((bm.group(1), trips))
                if cm:
                    children[name].append((cm.group(1), trips))
            elif " fusion(" in rhs:
                fm = re.search(r"calls=%?([\w\.\-]+)", rhs)
                if fm:
                    fusion_comps.add(fm.group(1))
            elif " call(" in rhs or " custom-call(" in rhs:
                fm = re.search(r"to_apply=%?([\w\.\-]+)", rhs)
                if fm:
                    children[name].append((fm.group(1), 1.0))
            elif " conditional(" in rhs:
                for fm in re.finditer(r"(?:true_computation|false_computation|"
                                      r"branch_computations=\{)([^}]*)", rhs):
                    for nm in _OPND_NAME.findall(fm.group(1)):
                        children[name].append((nm, 1.0))

    # entry = computation never referenced as child/fusion
    referenced = {c for lst in children.values() for c, _ in lst} | fusion_comps
    entries = [c for c in comps if c not in referenced]
    mult: dict[str, float] = defaultdict(float)
    seen: set[str] = set()

    def walk(comp: str, m: float):
        mult[comp] += m
        key = comp
        for child, k in children.get(key, []):
            walk(child, m * k)

    for e in entries:
        walk(e, 1.0)

    # ---- pass 3: accumulate costs ----
    costs = HloCosts()
    for name, lines in comps.items():
        for ln in lines:
            om = _OP_LINE.match(ln)
            if not om:
                continue
            opname, rhs = om.group(1), om.group(2)
            weight = mult.get(name, 0.0)
            if weight == 0.0:
                continue
            in_fusion = name in fusion_comps
            # --- dot flops (count inside fusions too: weight of the fusion's
            # caller applies transitively via mult of that computation; fused
            # dots live in fusion comps with mult 0 -> attribute them below)
            if " dot(" in rhs:
                out_shape, _ = _first_shape(rhs)
                lhs = _OPND_NAME.findall(
                    rhs[rhs.index("dot("):])
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                if out_shape is not None and lhs and cdims is not None:
                    lhs_type = types.get(lhs[0], "")
                    lhs_shape, _ = _first_shape(lhs_type)
                    k = 1.0
                    if lhs_shape:
                        for d in (cdims.group(1).split(",")
                                  if cdims.group(1) else []):
                            di = int(d)
                            if di < len(lhs_shape):
                                k *= lhs_shape[di]
                    out_n = 1
                    for d in out_shape:
                        out_n *= d
                    costs.flops += weight * 2.0 * out_n * k
            if in_fusion:
                continue
            # --- collectives (sync and async "-start" forms; skip "-done")
            for kind in COLL_KINDS:
                hit = None
                for form in (f" {kind}(", f" {kind}-start("):
                    if form in rhs:
                        hit = form
                        break
                if hit is None:
                    continue
                b = _all_shapes_bytes(rhs[:rhs.index(hit)])
                costs.coll_bytes[kind] += weight * b
                costs.coll_count[kind] += int(weight)
            # --- HBM proxy: output + operand bytes of schedulable ops
            skip = ("get-tuple-element", "tuple", "parameter", "constant",
                    "bitcast", "after-all")
            if any(rhs.lstrip().startswith(f"{s}") or f" {s}(" in rhs
                   for s in skip):
                continue
            out_b = _all_shapes_bytes(rhs[:rhs.index("(")]) if "(" in rhs \
                else _all_shapes_bytes(rhs)
            costs.bytes += weight * out_b
            # operand reads
            args = _OPERANDS.search(rhs[rhs.index("("):]) if "(" in rhs else None
            if args:
                for nm in _OPND_NAME.findall(args.group(1))[:8]:
                    t = types.get(nm)
                    if t:
                        costs.bytes += weight * _all_shapes_bytes(
                            t[:t.index("(")] if "(" in t else t)
    # fused dot attribution: fusion computations have mult 0; approximate by
    # giving each fusion comp the summed weight of its callers
    fusion_weight: dict[str, float] = defaultdict(float)
    for name, lines in comps.items():
        w = mult.get(name, 0.0)
        if w == 0.0:
            continue
        for ln in lines:
            om = _OP_LINE.match(ln)
            if om and " fusion(" in om.group(2):
                fm = re.search(r"calls=%?([\w\.\-]+)", om.group(2))
                if fm:
                    fusion_weight[fm.group(1)] += w
    for fname, w in fusion_weight.items():
        for ln in comps.get(fname, []):
            om = _OP_LINE.match(ln)
            if om and " dot(" in om.group(2):
                rhs = om.group(2)
                out_shape, _ = _first_shape(rhs)
                lhs = _OPND_NAME.findall(rhs[rhs.index("dot("):])
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                if out_shape is not None and lhs and cdims is not None:
                    lhs_shape, _ = _first_shape(types.get(lhs[0], ""))
                    k = 1.0
                    if lhs_shape:
                        for d in (cdims.group(1).split(",")
                                  if cdims.group(1) else []):
                            di = int(d)
                            if di < len(lhs_shape):
                                k *= lhs_shape[di]
                    out_n = 1
                    for d in out_shape:
                        out_n *= d
                    costs.flops += w * 2.0 * out_n * k
    return costs
