"""On-device multi-round driver: R federated rounds per device dispatch.

The seed trainers (``launch/train.py::train_loop``, ``benchmarks/run.py``)
drove every round from the host: sample a batch with numpy, dispatch one
jitted round, synchronously pull the loss back.  At bench scale that
host<->device round trip -- not the compressed-communication math the paper
analyzes -- dominates wall clock.  FetchSGD / FedSKETCH keep the whole
sketch-train loop resident on the accelerator; this driver does the same
(DESIGN.md §6):

* ``run_scan`` runs a chunk of rounds as ONE ``jax.lax.scan``: the scan body
  draws its own batch on device (``repro.data.device``), derives the round's
  sketch operator from the scanned round key (Remark 3.1 semantics
  unchanged -- same fold_in(key, t) chain as the host loop), and steps the
  round function.
* the ``(params, opt/baseline state, data state)`` carry is DONATED
  (``donate_argnums``) so large models update in place across chunks.
* metrics (loss, uplink bits) accumulate on device as stacked scan outputs
  and are fetched once per chunk, not once per round.
* the static sketch layout (``PackingPlan``) is built once OUTSIDE the trace
  by the caller and threaded in via ``functools.partial(round_fn, plan=...)``.

One interface serves ``safl_round``, ``clipped_safl_round`` and every
``baseline_round`` variant: any ``round_fn(params, state, batch, key, **kw)
-> (params, state, metrics)`` is scannable once it is purely functional
(baselines were made so in this PR -- an in-place ``state`` mutation is an
aliasing bug under donation).

``run_host_loop`` is the one-dispatch-per-round reference with the SAME key
and batch sequence; tests/test_driver.py pins scan == host loop
bit-for-bit, and benchmarks/run.py times both (fig1/<algo> vs
fig1/<algo>_scan).

Participation hooks (DESIGN.md §7, ``repro.fed``): ``participation=`` takes
a sampling policy whose ``mask(t)`` is evaluated inside the scan body and
passed to the round as ``part_mask`` (the per-round uplink-bits metric then
reports the SAMPLED cohort: per-client bits x mask sum); ``buffer=True``
additionally threads the traced round index ``t`` and the run's base key
into the round as ``t=``/``base_key=`` kwargs -- what an async staleness
buffer (``repro.fed.async_buffer``) needs to address its ring buffer and
re-derive older rounds' sketch operators at arrival time; ``faults=`` takes
a fault-injection policy (``repro.fed.faults``) whose per-round spec is
evaluated in the scan body and passed to the round as ``fault_spec``
(DESIGN.md §10 -- the sentinel config rides into the round via
``functools.partial``, like ``plan=``).
"""

from __future__ import annotations

import functools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.telemetry import PROBE_KEYS

Pytree = Any
# (params, state, batch, round_key, **kwargs) -> (params, state, metrics)
RoundFn = Callable[..., tuple[Pytree, dict, dict]]

# counter keys the guarded/buffered rounds emit next to the loss (fed/robust
# n_dropped/n_rejected, the sentinel's diverged flag, the async buffer's
# arrival_weight)
COUNTER_KEYS = ("n_dropped", "n_rejected", "diverged", "arrival_weight")

# every key a history dict / metric shard row may carry -- the single source
# of truth shared by this driver, the mesh driver (launch/train.py), the
# bench harness and tools/check_telemetry.py.  Which subset actually appears
# depends on the hooks bound into the round fn (guard counters) and on the
# static Telemetry config (probe keys; repro.obs.telemetry).
HISTORY_KEYS = ("loss", "uplink_bits") + COUNTER_KEYS + PROBE_KEYS


def _with_bits(metrics: dict, bits_per_round: Optional[int],
               mask=None, num_clients: Optional[int] = None) -> dict:
    """Stack the per-round uplink payload next to the loss (f32: 32d bits of
    a 100M-param model overflows int32).  With a participation mask the
    honest per-round figure is per-client bits x the EFFECTIVE post-guard
    cohort: the sampled cohort size (weighted masks carry theirs statically
    as ``"n"``) minus the round's fault drops and sentinel rejections -- a
    dropped payload never reaches the server and a rejected one is
    discarded, so neither is billed (the guarded rounds emit the
    ``n_dropped``/``n_rejected`` counters this reads; an unguarded round
    carries neither, leaving the no-fault program untouched).  Without a
    mask, ``bits_per_round`` is the caller's whole-cohort per-round total
    (seed semantics); when guard counters are present it is scaled by the
    surviving fraction ``(num_clients - lost) / num_clients``
    (``num_clients`` comes from the bound fault policy)."""
    if bits_per_round is None or "uplink_bits" in metrics:
        return metrics
    bits = jnp.asarray(bits_per_round, jnp.float32)
    lost = None
    if "n_dropped" in metrics or "n_rejected" in metrics:
        lost = sum(metrics[k] for k in ("n_dropped", "n_rejected")
                   if k in metrics)
    if mask is not None:
        n = mask["n"] if isinstance(mask, dict) else jnp.sum(mask)
        if lost is not None:
            n = n - lost
        bits = bits * n
    elif lost is not None and num_clients is not None:
        bits = bits * (num_clients - lost) / num_clients
    return {**metrics, "uplink_bits": bits}


def round_hook_kwargs(t, key, kwargs_fn, participation, buffer, faults=None):
    """Per-round traced kwargs for the round fn + the round's cohort mask.

    This is THE contract of the repro.fed hooks, shared by both drivers (the
    single-host scan here and the mesh scan in ``launch/train.py``): the
    cohort mask is evaluated in the scan body as a pure function of the
    absolute round index (``participation.mask(t)``) and handed to the round
    as ``part_mask``; a staleness buffer additionally receives the traced
    round index ``t`` and the run's base key ``base_key`` (ring-buffer
    addressing + per-generation operator re-derivation); a fault policy
    (``repro.fed.faults``) contributes the round's traced fault spec as
    ``fault_spec`` -- drawn against the run key, so the rollback
    supervisor's rekeyed retries redraw transient faults.  The static
    sentinel config is NOT threaded here: like ``plan=``, it binds into the
    round fn via ``functools.partial`` (it is not a pytree, and the host
    loop jits the round with these kwargs as traced arguments)."""
    kw = dict(kwargs_fn(t)) if kwargs_fn is not None else {}
    mask = None
    if participation is not None:
        mask = participation.mask(t)
        kw["part_mask"] = mask
    if buffer:
        kw["t"] = t
        kw["base_key"] = key
    if faults is not None:
        kw["fault_spec"] = faults.spec(t, key)
    return kw, mask


_round_kwargs = round_hook_kwargs         # back-compat alias


def make_chunk_fn(round_fn: RoundFn, sampler, num_rounds: int, *,
                  kwargs_fn=None, bits_per_round: Optional[int] = None,
                  donate: bool = True, participation=None,
                  buffer: bool = False, faults=None, microbatch=None,
                  codec=None):
    """Jit one scanned chunk of ``num_rounds`` rounds.

    Signature of the returned fn:
        (params, state, data_state, key, t0) ->
            (params, state, data_state, stacked_metrics)
    ``t0`` is a traced scalar so successive chunks reuse one executable.
    ``participation``/``buffer``/``faults`` are the repro.fed hooks (module
    docstring).  ``microbatch`` (static) binds the streamed-aggregation
    chunk size into the round fn (DESIGN.md §12); ``codec`` (static
    ``fed.codec.CodecConfig``) binds the payload codec (DESIGN.md §13).
    None leaves the round -- and the pinned programs -- untouched.
    """
    if microbatch is not None:
        round_fn = functools.partial(round_fn, microbatch=microbatch)
    if codec is not None:
        round_fn = functools.partial(round_fn, codec=codec)
    n_fault_clients = getattr(faults, "num_clients", None)

    def chunk(params, state, data_state, key, t0):
        def body(carry, t):
            params, state, dstate = carry
            dstate, batch = sampler.sample(dstate, t)
            kw, mask = round_hook_kwargs(t, key, kwargs_fn, participation,
                                         buffer, faults)
            params, state, m = round_fn(params, state, batch,
                                        jax.random.fold_in(key, t), **kw)
            return (params, state, dstate), _with_bits(m, bits_per_round,
                                                       mask,
                                                       n_fault_clients)

        (params, state, data_state), hist = jax.lax.scan(
            body, (params, state, data_state),
            t0 + jnp.arange(num_rounds, dtype=jnp.int32))
        return params, state, data_state, hist

    return jax.jit(chunk, donate_argnums=(0, 1, 2) if donate else ())


def run_scan(round_fn: RoundFn, sampler, params: Pytree, state: dict, *,
             rounds: int, key: jax.Array, chunk_size: int = 0,
             kwargs_fn=None, bits_per_round: Optional[int] = None,
             donate: bool = True, on_chunk=None, participation=None,
             buffer: bool = False, faults=None, microbatch=None,
             codec=None, start_round: int = 0,
             stream=None) -> tuple[Pytree, dict, dict]:
    """Run ``rounds`` federated rounds on device in scanned chunks.

    * ``sampler`` provides ``init_state()`` and ``sample(state, t)`` (see
      ``repro.data.device.DeviceBigramSampler``).
    * ``kwargs_fn(t)`` (optional) returns extra traced kwargs for the round,
      e.g. ``lambda t: {"lr_scale": sched(t)}`` for a cosine server LR.
    * ``chunk_size`` bounds rounds per dispatch (0 = all in one); metrics are
      fetched to host once per chunk, and ``on_chunk(t_done, params, state,
      chunk_hist)`` runs between chunks (logging / checkpointing).

    **Hook contract** (the full set, with each hook's pin class -- see
    DESIGN.md appendix "Pinning methodology" for the taxonomy):

    * ``participation=`` (policy object, ``repro.fed.participation``): the
      cohort mask is evaluated in the scan body as a pure function of the
      absolute round index and passed to the round as ``part_mask``.
      ``None`` routes at Python level (bitwise-neutral); an all-ones 0/1
      mask is bitwise the unmasked path by construction.
    * ``buffer=True`` (``repro.fed.async_buffer``): threads the traced
      round index ``t`` and the run's base key into the round.  The async
      round with ``delay="zero"`` is bitwise the synchronous program;
      nonzero delays are their own program family.
    * ``faults=`` (policy, ``repro.fed.faults``): per-round traced fault
      spec passed as ``fault_spec``; ``None`` is bitwise-neutral, enabled
      faults are their own family (extra guard counters in the scan ys).
    * ``sentinel=`` / ``telemetry=`` / ``plan=``: static configs, NOT
      threaded here -- bind them into ``round_fn`` via
      ``functools.partial`` before calling.  ``sentinel`` and ``telemetry``
      each start their own program family when enabled (extra scan
      outputs shift XLA fusion); ``None`` is bitwise-neutral.
    * ``microbatch=`` (static int): streams the round's aggregation over
      chunks of that many clients (DESIGN.md §12: peak payload memory
      O(microbatch x b_total) instead of O(G x b_total)); ``None`` (default)
      and any value >= G keep the materialized round program untouched
      (bitwise); a streaming value is its own family, allclose to the
      materialized path.
    * ``codec=`` (static ``fed.codec.CodecConfig``): binds the quantized
      payload codec (DESIGN.md §13) into the round like ``microbatch``;
      ``None`` (default) is bitwise-neutral, an enabled codec is its own
      family (it changes the trajectory by design) and replaces the
      ``uplink_bits`` fiction with the measured encoded size.  With
      ``codec.error_feedback`` the caller wraps ``state`` as
      ``{"opt": ..., "ef": ...}`` (``fed.codec.init_codec_state``).
    * ``stream=`` (below) only changes where metrics land, never the
      compiled round program.

    * ``start_round`` resumes mid-trajectory at an absolute round index --
      the restart path for a ``(t, key)`` checkpoint cursor
      (examples/train_lm.py).  Because every per-round stream (data,
      cohorts, delays, sketch operators) is a pure function of the absolute
      round index under ``key``, a resumed run replays the uninterrupted
      trajectory bit-identically (tests/test_resume.py).
    * ``stream`` (optional) is a ``repro.obs.shards.ShardWriter``: each
      chunk's history is fetched with an async device->host copy and
      appended as one JSONL metrics shard plus a wall-time span event
      (``compile=True`` marks the first dispatch of a chunk length), and the
      in-memory history accumulation is SKIPPED -- the returned ``history``
      is ``{}`` and the shard files are the record.  ``on_chunk`` still
      receives each chunk's host-side history either way.

    Returns ``(params, state, history)`` with ``history`` a dict of
    host-side ``(rounds - start_round,)`` arrays.  ``loss`` is always
    present; ``uplink_bits`` when ``bits_per_round`` is set; the
    ``COUNTER_KEYS`` subset the bound round emits (``n_dropped`` /
    ``n_rejected`` from the uplink guard, ``diverged`` from the sentinel,
    ``arrival_weight`` from the async buffer); and the ``PROBE_KEYS``
    subset selected by a static ``Telemetry`` config bound into the round
    (``repro.obs.telemetry``).  ``HISTORY_KEYS`` (module level) is the
    single source of truth for the full key set.
    """
    chunk_size = int(chunk_size) or int(rounds)
    data_state = sampler.init_state()
    compiled: dict[int, Callable] = {}
    hists = []
    t = int(start_round)
    while t < rounds:
        n = min(chunk_size, rounds - t)
        fresh = n not in compiled
        if fresh:                   # tail chunk of a different length re-jits
            compiled[n] = make_chunk_fn(
                round_fn, sampler, n, kwargs_fn=kwargs_fn,
                bits_per_round=bits_per_round, donate=donate,
                participation=participation, buffer=buffer, faults=faults,
                microbatch=microbatch, codec=codec)
        t_wall = time.perf_counter()
        params, state, data_state, hist = compiled[n](
            params, state, data_state, key, jnp.asarray(t, jnp.int32))
        if stream is not None:
            from repro.obs.shards import host_fetch
            hist = host_fetch(hist)            # async copy, ONE fetch
            dt = time.perf_counter() - t_wall
            stream.write_chunk(t, hist)
            stream.write_span(t, t + n, dt, compile=fresh)
        else:
            hist = jax.tree.map(np.asarray, hist)  # ONE fetch per chunk
            hists.append(hist)
        t += n
        if on_chunk is not None:
            on_chunk(t, params, state, hist)
    if not hists:   # streamed, or resumed at start_round == rounds
        return params, state, {}
    history = jax.tree.map(lambda *xs: np.concatenate(xs), *hists)
    return params, state, history


def run_host_loop(round_fn: RoundFn, sampler, params: Pytree, state: dict, *,
                  rounds: int, key: jax.Array, kwargs_fn=None,
                  bits_per_round: Optional[int] = None, donate: bool = True,
                  participation=None, buffer: bool = False, faults=None,
                  microbatch=None, codec=None,
                  start_round: int = 0) -> tuple[Pytree, dict, dict]:
    """One-dispatch-per-round reference loop with the scan driver's exact
    key/batch sequence (fold_in(key, t); device-side sampling), including
    the participation/buffer hooks (module docstring).

    Carries are still donated (ISSUE 2 satellite: no params/opt copy even on
    the non-scan path); the remaining cost vs ``run_scan`` is R dispatches
    and R blocking metric fetches -- precisely what fig1/<algo> vs
    fig1/<algo>_scan measures.
    """
    if microbatch is not None:
        round_fn = functools.partial(round_fn, microbatch=microbatch)
    if codec is not None:
        round_fn = functools.partial(round_fn, codec=codec)
    n_fault_clients = getattr(faults, "num_clients", None)
    data_state = sampler.init_state()
    sample = jax.jit(sampler.sample)
    step = jax.jit(round_fn, donate_argnums=(0, 1) if donate else ())
    hists = []
    for t in range(int(start_round), rounds):
        tt = jnp.asarray(t, jnp.int32)
        data_state, batch = sample(data_state, tt)
        kw, mask = round_hook_kwargs(tt, key, kwargs_fn, participation,
                                     buffer, faults)
        params, state, m = step(params, state, batch,
                                jax.random.fold_in(key, tt), **kw)
        hists.append(jax.tree.map(np.asarray,
                                  _with_bits(m, bits_per_round, mask,
                                             n_fault_clients)))
    history = jax.tree.map(lambda *xs: np.stack(xs), *hists)
    return params, state, history
