"""Batched greedy serving driver over the decode path (CPU-runnable).

Loads a named architecture from ``repro.configs`` (``--smoke`` shrinks it
to laptop scale while keeping the exact layer stack), initializes the
ring-buffered KV cache, optionally runs the audio encoder pass for
encoder-decoder configs (``encode_for_decode`` primes the cross-attention
cache), then greedy-decodes ``--batch`` sequences for ``--steps`` tokens
through one jitted ``decode_step`` and reports tokens/sec.  This is the
inference-side counterpart of the training drivers: the same model code
the federated rounds train is what serves, so a config or cache-layout
change that breaks decoding fails here (and in the CI dry-run) rather
than in a downstream consumer.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params
from repro.models.model import encode_for_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_params(cfg, jax.random.key(0))
    cache = init_cache(cfg, args.batch, args.max_seq)
    if cfg.encoder_layers:
        audio = jax.random.normal(
            jax.random.key(1), (args.batch, cfg.encoder_seq, cfg.d_model),
            cfg.dtype) * 0.02
        cache = encode_for_decode(cfg, params, cache, audio)
    step = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))
    tokens = jnp.zeros((args.batch, 1), jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.steps):
        logits, cache = step(params, cache, tokens,
                             jnp.asarray(i, jnp.int32))
        tokens = jnp.argmax(logits, axis=-1)[:, None]
    jax.block_until_ready(tokens)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {args.batch}x{args.steps} tokens in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.0f} tok/s, CPU)")


if __name__ == "__main__":
    main()
