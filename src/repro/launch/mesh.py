"""Production mesh construction (DESIGN §3).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets the host-device-count flag
before any jax initialization)."""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def _mesh(shape, axes) -> Mesh:
    # jax.sharding.AxisType landed after 0.4.x; Auto is the default there
    # anyway, so on older jax we simply omit the kwarg.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Single-device mesh for CPU tests/examples (1x1)."""
    return _mesh((1, 1), ("data", "model"))


def data_axis_size(mesh: Mesh) -> int:
    size = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        size *= mesh.shape["pod"]
    return size
