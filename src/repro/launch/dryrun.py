import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (architecture x input shape x
mesh) combination against ShapeDtypeStruct stand-ins (no allocation), print
memory_analysis() + cost_analysis(), and emit roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k [--multi-pod] [--step safl|fedopt] [--json out.json]

The two XLA_FLAGS lines above MUST stay the first statements in this module:
jax locks the device count on first init (hence also: never set this flag
globally -- smoke tests and benches must see 1 device)."""

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import (ASSIGNED, INPUT_SHAPES, get_config, input_specs,
                           shape_eligible)
from repro.core.adaptive import AdaConfig, init_opt_state
from repro.core.safl import SAFLConfig
from repro.core.sketch import SketchConfig
from repro.launch import roofline as RL
from repro.launch.mesh import data_axis_size, make_production_mesh
from repro.launch.train import (batch_pspecs, cache_pspecs, data_axes_of,
                                infer_batch_pspecs, make_fedopt_train_step,
                                make_prefill_step, make_safl_train_step,
                                make_serve_step, num_clients_of, opt_pspecs,
                                to_shardings)
from repro.models.model import param_shapes
from repro.models.sharding import param_pspecs, use_mesh

MEGA_PARAMS = 60e9  # configs above this use bf16 server moments (DESIGN §2)


def build_safl_cfg(cfg, *, sketch_kind="countsketch", ratio=1e-3,
                   local_steps=1, server="amsgrad") -> SAFLConfig:
    from repro.models.model import count_params_analytic
    mega = count_params_analytic(cfg) > MEGA_PARAMS
    return SAFLConfig(
        sketch=SketchConfig(kind=sketch_kind, ratio=ratio, min_b=64),
        server=AdaConfig(name=server, lr=1e-3,
                         moment_dtype=jnp.bfloat16 if mega else jnp.float32),
        client_lr=0.01, local_steps=local_steps)


def abstract_params(cfg):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(tuple(s), cfg.dtype),
                        param_shapes(cfg),
                        is_leaf=lambda x: isinstance(x, tuple))


def abstract_opt_state(server: AdaConfig, params_abs):
    mom = lambda: jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, server.moment_dtype), params_abs)
    out = {"step": jax.ShapeDtypeStruct((), jnp.int32)}
    if server.name in ("amsgrad", "adam", "sgdm"):
        out["m"] = mom()
    if server.name in ("amsgrad", "adam", "adagrad"):
        out["v"] = mom()
    if server.name == "amsgrad":
        out["vhat"] = mom()
    return out


def topology_for(cfg) -> str:
    from repro.models.model import count_params_analytic
    return "cross_silo" if count_params_analytic(cfg) > MEGA_PARAMS \
        else "cross_device"


def lower_one(arch: str, shape: str, *, multi_pod: bool, step_kind: str,
              local_steps: int = 1, ratio: float = 1e-3,
              sketch_kind: str = "countsketch", topology: str = "auto",
              serve_layout: str = "default", verbose: bool = True):
    """Returns (RooflineReport | None, status string)."""
    cfg = get_config(arch)
    ok, why = shape_eligible(cfg, shape)
    if not ok:
        return None, why
    sh = INPUT_SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.size
    daxes = data_axes_of(mesh)
    if topology == "auto":
        topology = topology_for(cfg)
    fsdp = topology == "cross_silo"
    G = num_clients_of(mesh, topology)

    t0 = time.time()
    with use_mesh(mesh):
        params_abs = abstract_params(cfg)
        pspecs = param_pspecs(params_abs, fsdp=fsdp)
        p_sh = to_shardings(mesh, pspecs)

        if sh.kind == "train":
            safl = build_safl_cfg(cfg, sketch_kind=sketch_kind, ratio=ratio,
                                  local_steps=local_steps)
            if step_kind == "fedopt":
                step, _ = make_fedopt_train_step(cfg, safl, mesh, topology)
            else:
                step, _ = make_safl_train_step(cfg, safl, mesh, topology)
            specs = input_specs(cfg, shape, num_clients=G,
                                local_steps=local_steps)
            batch = specs["batch"]
            opt_abs = abstract_opt_state(safl.server, params_abs)
            b_sh = to_shardings(mesh, batch_pspecs(batch, mesh, topology))
            o_sh = to_shardings(mesh, opt_pspecs(safl.server, pspecs))
            key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
            k_sh = NamedSharding(mesh, P())
            jitted = jax.jit(step,
                             in_shardings=(p_sh, o_sh, b_sh, k_sh),
                             out_shardings=(p_sh, o_sh, NamedSharding(mesh, P())),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch, key_abs)
        elif sh.kind == "prefill":
            step = make_prefill_step(cfg)
            specs = input_specs(cfg, shape)
            batch = specs["batch"]
            b_sh = to_shardings(mesh, infer_batch_pspecs(batch, daxes, mesh))
            out_sh = NamedSharding(mesh, P(daxes, "model"))
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                             out_shardings=out_sh)
            lowered = jitted.lower(params_abs, batch)
        else:  # decode
            step = make_serve_step(cfg)
            specs = input_specs(cfg, shape)
            cache, tokens, pos = specs["cache"], specs["tokens"], specs["pos"]
            import contextlib
            flat_ctx = contextlib.nullcontext()
            if serve_layout == "flat":
                from repro.launch.train import (flat_tp_cache_pspecs,
                                                flat_tp_pspecs)
                from repro.models.sharding import model_axis_substitution
                p_sh = to_shardings(mesh, flat_tp_pspecs(pspecs))
                c_sh = to_shardings(mesh, flat_tp_cache_pspecs(cache, mesh))
                flat_ctx = model_axis_substitution(("data", "model"))
            else:
                c_sh = to_shardings(mesh, cache_pspecs(cache, daxes, mesh))
            from repro.launch.train import _axes_size
            B_dec = specs["tokens"].shape[0]
            tok_axes = daxes if B_dec % _axes_size(mesh, daxes) == 0 else None
            t_sh = NamedSharding(mesh, P(tok_axes, None))
            s_sh = NamedSharding(mesh, P())
            jitted = jax.jit(step,
                             in_shardings=(p_sh, c_sh, t_sh, s_sh),
                             out_shardings=(NamedSharding(mesh,
                                                          P(tok_axes, None)),
                                            c_sh),
                             donate_argnums=(1,))
            with flat_ctx:
                lowered = jitted.lower(params_abs, cache, tokens, pos)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    model_flops = RL.model_flops_for(cfg, sh, local_steps=local_steps)
    mom_b = 2 if topology == "cross_silo" else 4
    amem = RL.analytic_memory_bytes(cfg, sh, chips, moment_bytes=mom_b,
                                    local_steps=local_steps)
    rep = RL.analyze(compiled, arch=arch, shape=shape, mesh_name=mesh_name,
                     chips=chips, model_flops=model_flops,
                     analytic_mem_bytes=amem,
                     note=(f"step={step_kind} topo={topology} "
                           f"serve={serve_layout} "
                           f"lower={t_lower:.0f}s compile={t_compile:.0f}s"))
    if verbose:
        print(f"--- {arch} x {shape} x {mesh_name} [{step_kind}] ---")
        print("memory_analysis:", rep.memory_report)
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        print("cost_analysis: flops=%.3e bytes=%.3e" %
              (float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0))))
        print("collectives:", {k: v for k, v in rep.coll_breakdown.items()
                               if k != "counts"})
        print(RL.format_row(rep))
        sys.stdout.flush()
    return rep, "ok"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--step", default="safl", choices=["safl", "fedopt"])
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--ratio", type=float, default=1e-3)
    ap.add_argument("--sketch", default="countsketch")
    ap.add_argument("--topology", default="auto",
                    choices=["auto", "cross_device", "cross_device_dp",
                             "cross_silo"])
    ap.add_argument("--serve-layout", default="default",
                    choices=["default", "flat"])
    ap.add_argument("--json", default=None, help="append reports to this file")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    reports, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rep, status = lower_one(
                        arch, shape, multi_pod=mp, step_kind=args.step,
                        local_steps=args.local_steps, ratio=args.ratio,
                        sketch_kind=args.sketch, topology=args.topology,
                        serve_layout=args.serve_layout)
                    if rep is None:
                        print(f"--- {arch} x {shape} x "
                              f"{'2x16x16' if mp else '16x16'}: {status}")
                    else:
                        reports.append(rep)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"!!! FAIL {arch} x {shape} mp={mp}: {e!r}")
                finally:
                    jax.clear_caches()
    if args.json:
        with open(args.json, "a") as f:
            for r in reports:
                f.write(json.dumps(r.to_json()) + "\n")
    print(f"\n{len(reports)} ok, {len(failures)} failed")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
