"""llama3.2-1b [dense]: 16L d_model=2048, 32H (GQA kv=8), d_ff=8192,
vocab=128256, tied embeddings, rope theta 5e5.
[hf:meta-llama/Llama-3.2-1B]"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", arch_type="dense",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, rope_theta=500000.0, tie_embeddings=True,
    dtype=jnp.bfloat16, source="hf:meta-llama/Llama-3.2-1B",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=256, dtype=jnp.float32)
