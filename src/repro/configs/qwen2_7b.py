"""qwen2-7b [dense]: 28L d_model=3584, 28H (GQA kv=4), d_ff=18944,
vocab=152064, QKV bias.  [arXiv:2407.10671]"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", arch_type="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064, attn_bias=True, rope_theta=1e6,
    dtype=jnp.bfloat16, source="arXiv:2407.10671",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=256, dtype=jnp.float32)
