"""whisper-large-v3 [audio]: 32L enc + 32L dec, d_model=1280, 20H (kv=20),
d_ff=5120, vocab=51866; conv/mel frontend is a STUB -- input_specs provides
precomputed frame embeddings (1500 frames).  [arXiv:2212.04356]"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", arch_type="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    norm_kind="ln", mlp_kind="gelu", pos_kind="sinusoidal",
    encoder_layers=32, encoder_seq=1500, cross_attention=True,
    frontend="audio", dtype=jnp.bfloat16, source="arXiv:2212.04356",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, encoder_layers=2, d_model=128, num_heads=4,
    num_kv_heads=4, d_ff=256, vocab_size=256, encoder_seq=24,
    dtype=jnp.float32)
