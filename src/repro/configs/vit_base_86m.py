"""vit_base_86m: the paper's §5 vision backbone (ViT-Base, 86M),
LM-adapted transformer of the same shape (the paper finetunes it on
CIFAR-10).  [paper §5; arXiv:2010.11929]"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="vit-base-86m", arch_type="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=1000, norm_kind="ln", mlp_kind="gelu",
    pos_kind="sinusoidal",
    dtype=jnp.float32, source="paper §5 / arXiv:2010.11929",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=256)
