"""falcon-mamba-7b [ssm]: 64L d_model=4096 attention-free Mamba-1,
ssm_state=16, vocab=65024.  [arXiv:2410.05355]"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", arch_type="ssm",
    num_layers=64, d_model=4096, vocab_size=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    dtype=jnp.bfloat16, source="arXiv:2410.05355",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, vocab_size=256, ssm_state=8,
    dtype=jnp.float32)
