"""h2o-danube-1.8b [dense]: 24L d_model=2560, 32H (GQA kv=8), d_ff=6912,
vocab=32000, llama+mistral mix with sliding-window attention (window=4096)
=> long_500k eligible.  [arXiv:2401.16818]"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", arch_type="dense",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=6912, vocab_size=32000, sliding_window=4096,
    dtype=jnp.bfloat16, source="arXiv:2401.16818",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=256, sliding_window=16, dtype=jnp.float32)
