"""qwen1.5-4b [dense]: 40L d_model=2560, 20H (kv=20, MHA), d_ff=6912,
vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5-0.5B family scaling]"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", arch_type="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
    d_ff=6912, vocab_size=151936, attn_bias=True,
    dtype=jnp.bfloat16, source="hf:Qwen/Qwen1.5-0.5B",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=256, dtype=jnp.float32)
