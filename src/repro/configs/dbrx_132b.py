"""dbrx-132b [moe]: 40L d_model=6144, 48H (GQA kv=8), d_ff=10752,
vocab=100352, fine-grained MoE 16 experts top-4, LayerNorm.
[hf:databricks/dbrx-base]"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", arch_type="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352, norm_kind="ln",
    num_experts=16, moe_top_k=4, moe_d_ff=10752, rope_theta=5e5,
    dtype=jnp.bfloat16, source="hf:databricks/dbrx-base",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=256, num_experts=4, moe_top_k=2,
    moe_d_ff=64, dtype=jnp.float32)
