"""bert_100m: the paper's §5 language backbone (BERT-base scale, 100M),
LM-adapted (decoder-only) for this framework's task suite.
[paper §5; arXiv:1810.04805]"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="bert-100m", arch_type="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=30522, norm_kind="ln", mlp_kind="gelu",
    pos_kind="sinusoidal",
    dtype=jnp.float32, source="paper §5 / arXiv:1810.04805",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=256)
