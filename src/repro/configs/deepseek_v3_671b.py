"""deepseek-v3-671b [moe]: 61L d_model=7168, 128H MLA, vocab=129280,
MoE 256 routed experts top-8 + 1 shared, expert d_ff=2048 (assigned),
dense d_ff=18432 on the 3 leading dense layers, MTP auxiliary head.
[arXiv:2412.19437]"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", arch_type="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=18432, vocab_size=129280,
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
    num_experts=256, moe_top_k=8, num_shared_experts=1, moe_d_ff=2048,
    first_dense_layers=3, mtp=True,
    dtype=jnp.bfloat16, source="arXiv:2412.19437",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, first_dense_layers=1, d_model=128, num_heads=4,
    num_kv_heads=4, d_ff=256, vocab_size=256,
    q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8, qk_nope_dim=16,
    v_head_dim=16, num_experts=4, moe_top_k=2, moe_d_ff=64,
    dtype=jnp.float32)
