"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192, 64H (GQA kv=8),
d_ff=24576, vocab=65536, MoE 16e top-2, Mamba:attention 7:1 interleave
(attn at position 4 of each 8-layer block), MoE every other layer.
[arXiv:2403.19887]"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", arch_type="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    num_experts=16, moe_top_k=2, moe_every=2, moe_offset=1,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    attn_every=8, attn_offset=4,
    dtype=jnp.bfloat16, source="arXiv:2403.19887",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=8, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=256, num_experts=4, ssm_state=8,
    dtype=jnp.float32)
