"""qwen2-vl-7b [vlm]: 28L d_model=3584, 28H (GQA kv=4), d_ff=18944,
vocab=152064, M-RoPE; the ViT frontend is a STUB -- input_specs provides
patch embeddings (256 tokens, 16x16 grid stand-in for dynamic resolution).
[arXiv:2409.12191]"""
import dataclasses
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", arch_type="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    attn_bias=True, pos_kind="mrope", mrope_sections=(16, 24, 24),
    rope_theta=1e6, frontend="vision", num_frontend_tokens=256,
    dtype=jnp.bfloat16, source="arXiv:2409.12191",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=256, mrope_sections=(8, 4, 4),
    num_frontend_tokens=16, dtype=jnp.float32)
