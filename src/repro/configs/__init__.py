"""Architecture registry + input-shape table + ShapeDtypeStruct specs.

Every assigned architecture is a module exposing ``CONFIG`` (the exact
published configuration, source cited in ``ModelConfig.source``) and
``SMOKE`` (a reduced same-family variant: <=2 scan blocks, d_model<=512,
<=4 experts) for the CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

ARCHS = [
    "falcon_mamba_7b",
    "whisper_large_v3",
    "jamba_1_5_large_398b",
    "qwen2_vl_7b",
    "h2o_danube_1_8b",
    "llama3_2_1b",
    "qwen1_5_4b",
    "deepseek_v3_671b",
    "qwen2_7b",
    "dbrx_132b",
    # the paper's own experimental backbones (§5), LM-adapted
    "bert_100m",
    "vit_base_86m",
]

ASSIGNED = ARCHS[:10]

# canonical ids with dashes, as in the assignment table
def canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524288, 1,   "decode"),
}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.SMOKE if smoke else mod.CONFIG


def long_context_eligible(cfg: ModelConfig) -> bool:
    """long_500k needs sub-quadratic attention (DESIGN §4): SSM, hybrid, or
    native sliding-window.  Pure full-attention archs are skipped."""
    if cfg.arch_type in ("ssm", "hybrid"):
        return True
    return cfg.sliding_window > 0


def shape_eligible(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and not long_context_eligible(cfg):
        return False, "SKIP(full-attention: no sub-quadratic variant)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: str, *, num_clients: int = 16,
                local_steps: int = 1) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    from repro.models.model import cache_shapes, _cache_dtype  # lazy import
    sh = INPUT_SHAPES[shape]
    f = lambda s, d=jnp.int32: jax.ShapeDtypeStruct(tuple(s), d)
    P = cfg.num_frontend_tokens if cfg.frontend == "vision" else 0

    if sh.kind == "train":
        g, k = num_clients, local_steps
        assert sh.global_batch % (g * k) == 0
        mb = sh.global_batch // (g * k)
        batch = {"tokens": f((g, k, mb, sh.seq_len - P))}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = f((g, k, mb, P, cfg.d_model), cfg.dtype)
        if cfg.frontend == "audio":
            batch["audio_embeds"] = f((g, k, mb, cfg.encoder_seq, cfg.d_model),
                                      cfg.dtype)
        return {"batch": batch}

    if sh.kind == "prefill":
        b = sh.global_batch
        batch = {"tokens": f((b, sh.seq_len - P))}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = f((b, P, cfg.d_model), cfg.dtype)
        if cfg.frontend == "audio":
            batch["audio_embeds"] = f((b, cfg.encoder_seq, cfg.d_model),
                                      cfg.dtype)
        return {"batch": batch}

    # decode: one new token against a seq_len-deep cache
    b = sh.global_batch
    shapes = cache_shapes(cfg, b, sh.seq_len)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    leaves = []
    for path, s in flat:
        spath = "/".join(str(getattr(k2, "key", k2)) for k2 in path)
        leaves.append(f(s, _cache_dtype(cfg, spath)))
    cache = jax.tree_util.tree_unflatten(treedef, leaves)
    return {"cache": cache, "tokens": f((b, 1)),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
