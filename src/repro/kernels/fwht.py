"""Pallas TPU kernel: fast Walsh-Hadamard transform (SRHT backbone).

TPU adaptation (DESIGN.md §2): the FWHT is memory-bound and MXU-hostile, so
we run it on the VPU with all butterflies of a row resident in VMEM.  A
length-n transform (n = n1 * n2 power of two) uses the Kronecker identity

    H_n = H_{n1} (x) H_{n2}    =>    FWHT(x) = H_{n1} X H_{n2}

with X = x.reshape(n1, n2):  pass 1 applies H_{n2} along rows, pass 2 applies
H_{n1} along rows of X^T.  Each kernel call transforms a (ROWS_PER_BLOCK, C)
tile fully inside VMEM with log2(C) unrolled butterfly stages.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8          # rows transformed per grid step
MAX_C = 4096           # max per-row transform length held in VMEM


def _fwht_rows_kernel(x_ref, o_ref, *, c: int):
    """FWHT along the last axis of a (ROW_BLOCK, c) tile, fully in VMEM."""
    x = x_ref[...]
    rows = x.shape[0]
    h = 1
    while h < c:
        x = x.reshape(rows, c // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.concatenate([a + b, a - b], axis=-1)  # (rows, pairs, 2h)
        x = x.reshape(rows, c)
        h *= 2
    o_ref[...] = x


def fwht_rows_pallas(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Unnormalized FWHT along the last axis of (R, C); C a power of 2."""
    r, c = x.shape
    assert c & (c - 1) == 0 and c <= MAX_C
    r_pad = ((r + ROW_BLOCK - 1) // ROW_BLOCK) * ROW_BLOCK
    xp = jnp.pad(x.astype(jnp.float32), ((0, r_pad - r), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_fwht_rows_kernel, c=c),
        grid=(r_pad // ROW_BLOCK,),
        in_specs=[pl.BlockSpec((ROW_BLOCK, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_BLOCK, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, c), jnp.float32),
        interpret=interpret,
    )(xp)
    return out[:r]


def fwht_pallas(v: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Unnormalized FWHT of a 1-D vector whose length is a power of 2."""
    (n,) = v.shape
    assert n & (n - 1) == 0
    if n <= MAX_C:
        return fwht_rows_pallas(v.reshape(1, n), interpret=interpret).reshape(n)
    # factor n = n1 * n2 with n2 <= MAX_C (two-level Kronecker covers n <= 16M)
    n2 = MAX_C
    n1 = n // n2
    assert n1 <= MAX_C, "fwht_pallas supports n <= MAX_C**2 (16M)"
    xm = v.reshape(n1, n2)
    xm = fwht_rows_pallas(xm, interpret=interpret)          # H_{n2} along rows
    xm = fwht_rows_pallas(xm.T, interpret=interpret).T      # H_{n1} along cols
    return xm.reshape(n)
