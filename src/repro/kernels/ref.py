"""Pure-jnp/numpy oracles for every Pallas kernel (allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def countsketch_ref(x: jax.Array, h: jax.Array, b: int) -> jax.Array:
    """Oracle for kernels/countsketch.py: plain segment sum."""
    return jax.ops.segment_sum(x.astype(jnp.float32), h, num_segments=b)


def fwht_ref(x: np.ndarray) -> np.ndarray:
    """Oracle for kernels/fwht.py: textbook in-place butterfly, float64."""
    x = np.asarray(x, dtype=np.float64).copy()
    n = x.shape[-1]
    h = 1
    while h < n:
        for i in range(0, n, h * 2):
            for j in range(i, i + h):
                a = x[..., j].copy()
                b = x[..., j + h].copy()
                x[..., j] = a + b
                x[..., j + h] = a - b
        h *= 2
    return x


def gaussian_tile_ref(seed: int, tile: int, tile_n: int, b: int) -> np.ndarray:
    """Oracle for the in-kernel counter PRNG: same splitmix32 + Box-Muller
    evaluated with numpy uint32 arithmetic."""
    rows, cols = np.meshgrid(np.arange(tile_n, dtype=np.uint64),
                             np.arange(b, dtype=np.uint64), indexing="ij")
    base = (np.uint64(seed) * np.uint64(0x9E3779B1)
            + np.uint64(tile) * np.uint64(0x85EBCA77)) & np.uint64(0xFFFFFFFF)
    ctr = (base + rows * np.uint64(2 * b) + cols * np.uint64(2)) & np.uint64(0xFFFFFFFF)

    def mix(x):
        x = (x + np.uint64(0x9E3779B9)) & np.uint64(0xFFFFFFFF)
        x = ((x ^ (x >> np.uint64(16))) * np.uint64(0x85EBCA6B)) & np.uint64(0xFFFFFFFF)
        x = ((x ^ (x >> np.uint64(13))) * np.uint64(0xC2B2AE35)) & np.uint64(0xFFFFFFFF)
        return x ^ (x >> np.uint64(16))

    def unif(bits):
        return ((bits >> np.uint64(8)).astype(np.float32) + 1.0) * np.float32(2.0 ** -24)

    u1 = unif(mix(ctr))
    u2 = unif(mix((ctr + np.uint64(1)) & np.uint64(0xFFFFFFFF)))
    r = np.sqrt(-2.0 * np.log(u1.astype(np.float64)))
    return (r * np.cos(2.0 * np.pi * u2.astype(np.float64))).astype(np.float32)


def gaussian_sk_ref(seed: int, x: np.ndarray, b: int, tile_n: int = 512) -> np.ndarray:
    """Oracle for gaussian_sk_pallas: explicit tile-by-tile R materialization."""
    n = x.shape[0]
    n_pad = ((n + tile_n - 1) // tile_n) * tile_n
    xp = np.pad(np.asarray(x, np.float32), (0, n_pad - n))
    acc = np.zeros((b,), np.float64)
    for t in range(n_pad // tile_n):
        rt = gaussian_tile_ref(seed, t, tile_n, b)
        acc += xp[t * tile_n:(t + 1) * tile_n].astype(np.float64) @ rt
    return (acc / np.sqrt(b)).astype(np.float32)


def gaussian_desk_ref(seed: int, s: np.ndarray, n: int, tile_n: int = 512) -> np.ndarray:
    b = s.shape[0]
    n_pad = ((n + tile_n - 1) // tile_n) * tile_n
    out = np.zeros((n_pad,), np.float64)
    for t in range(n_pad // tile_n):
        rt = gaussian_tile_ref(seed, t, tile_n, b)
        out[t * tile_n:(t + 1) * tile_n] = rt @ np.asarray(s, np.float64)
    return (out[:n] / np.sqrt(b)).astype(np.float32)
