"""Pallas TPU kernel: on-the-fly Gaussian sketch (sk/desk of Lemma A.2).

TPU adaptation (DESIGN.md §2): the b x n Gaussian matrix R is never stored.
Each grid step regenerates one (TILE_N, b) tile of R^T from a counter-based
PRNG keyed on (seed, tile, position) and immediately contracts it on the MXU:

    sk:   out[b]      += x_tile[TILE_N] @ R_tile[TILE_N, b]      (accumulate)
    desk: out[TILE_N]  = R_tile[TILE_N, b] @ s[b]                (per tile)

The PRNG is a splitmix32-style integer mixer in plain jnp ops, so the kernel
is bit-identical under interpret=True (CPU validation) and compiled TPU, and
sk/desk regenerate exactly the same R (tested via adjointness
<sk(v), s> == <v, desk(s)>).  Normals come from Box-Muller on two mixed
uint32 streams.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 512  # input elements per grid step; (TILE_N, b) tile of R in VMEM


def _splitmix32(x: jax.Array) -> jax.Array:
    """Counter-based 32-bit mixer (splitmix64 constants truncated to 32b)."""
    x = (x + jnp.uint32(0x9E3779B9)).astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    return x ^ (x >> 16)


def _uniform01(bits: jax.Array) -> jax.Array:
    # top 24 bits -> (0, 1]; never exactly 0 so log() is safe
    return ((bits >> jnp.uint32(8)).astype(jnp.float32) + 1.0) * (2.0 ** -24)


def _gauss_tile(seed: jax.Array, tile: jax.Array, tile_n: int, b: int) -> jax.Array:
    """Deterministic (tile_n, b) tile of R^T ~ N(0,1), via Box-Muller."""
    rows = jax.lax.broadcasted_iota(jnp.uint32, (tile_n, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (tile_n, b), 1)
    # unique counter per (seed, tile, element, stream)
    base = (seed.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
            + tile.astype(jnp.uint32) * jnp.uint32(0x85EBCA77))
    ctr = base + rows * jnp.uint32(2 * b) + cols * jnp.uint32(2)
    u1 = _uniform01(_splitmix32(ctr))
    u2 = _uniform01(_splitmix32(ctr + jnp.uint32(1)))
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(2.0 * jnp.pi * u2)


def _sk_kernel(seed_ref, x_ref, o_ref, *, b: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    rt = _gauss_tile(seed_ref[0], i, TILE_N, b)           # (TILE_N, b)
    x = x_ref[...]                                        # (1, TILE_N)
    o_ref[...] += jnp.dot(x, rt, preferred_element_type=jnp.float32)


def _desk_kernel(seed_ref, s_ref, o_ref, *, b: int):
    i = pl.program_id(0)
    rt = _gauss_tile(seed_ref[0], i, TILE_N, b)           # (TILE_N, b)
    s = s_ref[...]                                        # (1, b)
    o_ref[...] = jnp.dot(s, rt.T, preferred_element_type=jnp.float32)


def gaussian_sk_pallas(seed: jax.Array, x: jax.Array, b: int, *,
                       interpret: bool = True) -> jax.Array:
    """sk(x) = R x / sqrt(b) with R regenerated tile-by-tile in-kernel."""
    n = x.shape[0]
    n_pad = ((n + TILE_N - 1) // TILE_N) * TILE_N
    xp = jnp.pad(x.astype(jnp.float32), (0, n_pad - n)).reshape(1, n_pad)
    out = pl.pallas_call(
        functools.partial(_sk_kernel, b=b),
        grid=(n_pad // TILE_N,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # scalar seed, whole array
            pl.BlockSpec((1, TILE_N), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, b), jnp.float32),
        interpret=interpret,
    )(seed.reshape(1).astype(jnp.uint32), xp)
    return out.reshape(b) / jnp.sqrt(jnp.asarray(b, jnp.float32))


def gaussian_desk_pallas(seed: jax.Array, s: jax.Array, n: int, *,
                         interpret: bool = True) -> jax.Array:
    """desk(s) = R^T s / sqrt(b), regenerating the same R tiles as sk."""
    b = s.shape[0]
    n_pad = ((n + TILE_N - 1) // TILE_N) * TILE_N
    sp = s.astype(jnp.float32).reshape(1, b)
    out = pl.pallas_call(
        functools.partial(_desk_kernel, b=b),
        grid=(n_pad // TILE_N,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, TILE_N), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.float32),
        interpret=interpret,
    )(seed.reshape(1).astype(jnp.uint32), sp)
    return out.reshape(n_pad)[:n] / jnp.sqrt(jnp.asarray(b, jnp.float32))
