"""Jit'd public wrappers over the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True``; on a
real TPU backend they compile through Mosaic.  ``repro.core.sketch`` routes
through these when ``SketchConfig.use_pallas`` is set.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.countsketch import (countsketch_clients_pallas,
                                       countsketch_pallas)
from repro.kernels.fwht import MAX_C, fwht_pallas, fwht_rows_pallas
from repro.kernels.gaussian_sketch import gaussian_desk_pallas, gaussian_sk_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("b",))
def countsketch(x: jax.Array, h: jax.Array, b: int) -> jax.Array:
    """Count-sketch aggregation: out[j] = sum_{h[i]==j} x[i].

    Any ``b`` is supported: the kernel splits the output into VMEM-sized
    b-blocks on a dedicated grid axis (see kernels/countsketch.py).
    """
    return countsketch_pallas(x, h, b, interpret=_interpret())


@partial(jax.jit, static_argnames=("b",))
def countsketch_clients(x: jax.Array, h: jax.Array, b: int) -> jax.Array:
    """Batched count-sketch over the client axis: x (G, n) -> (G, b).

    One Pallas launch for all G clients; the per-tile one-hot is built once
    and shared by every client row (packed engine hot path, DESIGN.md §4).
    """
    return countsketch_clients_pallas(x, h, b, interpret=_interpret())


@jax.jit
def fwht(v: jax.Array) -> jax.Array:
    """Unnormalized fast Walsh-Hadamard transform of a pow2-length vector."""
    return fwht_pallas(v, interpret=_interpret())


@jax.jit
def fwht_rows(x: jax.Array) -> jax.Array:
    """Unnormalized FWHT along the last axis of an (R, C) batch.

    Rows up to MAX_C transform in one grid sweep; longer rows fall back to
    the per-row two-level Kronecker path of ``fwht_pallas``.
    """
    if x.shape[-1] <= MAX_C:
        return fwht_rows_pallas(x, interpret=_interpret())
    return jnp.stack([fwht_pallas(row, interpret=_interpret()) for row in x])


@partial(jax.jit, static_argnames=("b",))
def gaussian_sk(seed: jax.Array, x: jax.Array, b: int) -> jax.Array:
    return gaussian_sk_pallas(seed, x, b, interpret=_interpret())


@partial(jax.jit, static_argnames=("n",))
def gaussian_desk(seed: jax.Array, s: jax.Array, n: int) -> jax.Array:
    return gaussian_desk_pallas(seed, s, n, interpret=_interpret())
