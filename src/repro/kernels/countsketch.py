"""Pallas TPU kernel: Count-Sketch aggregation (sk of paper Lemma A.3).

TPU adaptation (DESIGN.md §2): TPUs have no fast scatter, so the classic
``out[h[i]] += s[i] * v[i]`` loop is reformulated as a tile-local one-hot
matmul that runs on the MXU:

    for each tile of T input elements:
        onehot[T, b] = (h_tile[:, None] == iota_b[None, :])
        out[b]      += x_tile[T] @ onehot          # MXU matmul

The (T, b) one-hot tile lives in VMEM; the (b,) accumulator is revisited by
every grid step (TPU grid is sequential over the last axis, so accumulation
into the same output block is well-defined).

Input ``x`` is the sign-multiplied vector ``v * s`` (signs applied by the
caller so the kernel is a pure semantic of "segment-sum with hash h").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile of input elements processed per grid step. 8*128-aligned for the VPU;
# the (TILE_N, b) one-hot at b=2048 is 8 MiB fp32 -> we matmul in bf16-free
# fp32 which still fits comfortably in 16 MiB VMEM for b <= 2048 per call;
# larger b is split by the wrapper in ops.py.
TILE_N = 1024


def _countsketch_kernel(x_ref, h_ref, o_ref, *, b: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (1, TILE_N) f32
    h = h_ref[...]  # (1, TILE_N) i32
    cols = jax.lax.broadcasted_iota(jnp.int32, (TILE_N, b), 1)
    onehot = (h.reshape(TILE_N, 1) == cols).astype(x.dtype)  # (TILE_N, b)
    o_ref[...] += jnp.dot(x, onehot, preferred_element_type=jnp.float32)


def countsketch_pallas(x: jax.Array, h: jax.Array, b: int, *,
                       interpret: bool = True) -> jax.Array:
    """Count-sketch ``segment_sum(x, h, b)`` via the Pallas kernel.

    x: (n,) float32 (already sign-multiplied), h: (n,) int32 in [0, b).
    """
    n = x.shape[0]
    n_pad = ((n + TILE_N - 1) // TILE_N) * TILE_N
    # pad x with zeros -> padded elements contribute nothing wherever hashed
    xp = jnp.pad(x.astype(jnp.float32), (0, n_pad - n)).reshape(1, n_pad)
    hp = jnp.pad(h.astype(jnp.int32), (0, n_pad - n)).reshape(1, n_pad)
    grid = (n_pad // TILE_N,)
    out = pl.pallas_call(
        functools.partial(_countsketch_kernel, b=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, TILE_N), lambda i: (0, i)),
            pl.BlockSpec((1, TILE_N), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, b), jnp.float32),
        interpret=interpret,
    )(xp, hp)
    return out.reshape(b)
