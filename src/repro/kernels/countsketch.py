"""Pallas TPU kernel: Count-Sketch aggregation (sk of paper Lemma A.3).

TPU adaptation (DESIGN.md §2): TPUs have no fast scatter, so the classic
``out[h[i]] += s[i] * v[i]`` loop is reformulated as a tile-local one-hot
matmul that runs on the MXU:

    for each tile of T input elements:
        onehot[T, b] = (h_tile[:, None] == iota_b[None, :])
        out[b]      += x_tile[T] @ onehot          # MXU matmul

The batched variant serves the packed sketch engine (DESIGN.md §4): the
whole round's uplink is ONE launch over a ``(client, b-block, tile)`` grid.
The (TILE_N, B_BLOCK) one-hot is built once per (b-block, tile) step and
reused by every client row of the block through a single
``(G_BLOCK, TILE_N) @ (TILE_N, B_BLOCK)`` MXU matmul -- instead of the
per-leaf loop's O(G x num_leaves) kernel calls per round.

VMEM: the fp32 one-hot tile is capped at (TILE_N, B_BLOCK) = 8 MiB; sketch
sizes beyond ``MAX_B_BLOCK`` are handled by the b-block grid axis (each
block compares ``h`` against its own column window), so any ``b`` fits.

Input ``x`` is the sign-multiplied vector ``v * s`` (signs applied by the
caller so the kernel is a pure semantic of "segment-sum with hash h").
The TPU grid is sequential over the LAST axis, so revisiting the same
output block across tile steps accumulates deterministically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 1024      # input elements per grid step (8*128-aligned for the VPU)
MAX_B_BLOCK = 2048  # max output slots per block: (1024, 2048) fp32 = 8 MiB
G_BLOCK = 8        # client rows per block (fp32 sublane multiple)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _countsketch_kernel(x_ref, h_ref, o_ref, *, b_block: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                      # (g_block, TILE_N) f32
    h = h_ref[...]                      # (1, TILE_N) i32
    tile_n = x.shape[1]
    # this b-block owns columns [bb * b_block, (bb+1) * b_block)
    cols = (jax.lax.broadcasted_iota(jnp.int32, (tile_n, b_block), 1)
            + pl.program_id(1) * b_block)
    onehot = (h.reshape(tile_n, 1) == cols).astype(x.dtype)   # (TILE_N, b_block)
    o_ref[...] += jnp.dot(x, onehot, preferred_element_type=jnp.float32)


def countsketch_clients_pallas(x: jax.Array, h: jax.Array, b: int, *,
                               interpret: bool = True) -> jax.Array:
    """Batched count-sketch ``out[g, j] = sum_{h[i]==j} x[g, i]``.

    x: (G, n) float32 (already sign-multiplied), h: (n,) int32 in [0, b),
    shared across the G client rows (paper Remark 3.1: one operator per
    round).  Returns (G, b) float32.  Any ``b`` is supported via the
    b-block grid axis.
    """
    g, n = x.shape
    g_block = G_BLOCK if g > 1 else 1
    g_pad = _round_up(g, g_block)
    n_pad = _round_up(n, TILE_N)
    b_block = min(MAX_B_BLOCK, _round_up(b, 128))
    b_pad = _round_up(b, b_block)
    # pad x with zero rows/cols -> padded elements contribute nothing
    xp = jnp.pad(x.astype(jnp.float32), ((0, g_pad - g), (0, n_pad - n)))
    hp = jnp.pad(h.astype(jnp.int32), (0, n_pad - n),
                 constant_values=-1).reshape(1, n_pad)
    grid = (g_pad // g_block, b_pad // b_block, n_pad // TILE_N)
    out = pl.pallas_call(
        functools.partial(_countsketch_kernel, b_block=b_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((g_block, TILE_N), lambda g_, bb, t: (g_, t)),
            pl.BlockSpec((1, TILE_N), lambda g_, bb, t: (0, t)),
        ],
        out_specs=pl.BlockSpec((g_block, b_block), lambda g_, bb, t: (g_, bb)),
        out_shape=jax.ShapeDtypeStruct((g_pad, b_pad), jnp.float32),
        interpret=interpret,
    )(xp, hp)
    return out[:g, :b]


def countsketch_pallas(x: jax.Array, h: jax.Array, b: int, *,
                       interpret: bool = True) -> jax.Array:
    """Count-sketch ``segment_sum(x, h, b)`` via the batched Pallas kernel.

    x: (n,) float32 (already sign-multiplied), h: (n,) int32 in [0, b).
    """
    return countsketch_clients_pallas(x.reshape(1, -1), h, b,
                                      interpret=interpret).reshape(b)
