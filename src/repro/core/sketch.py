"""Random linear sketching operators for SAFL (paper §3.2).

Implements the three sketch families the paper's theory covers:

* ``gaussian``    -- i.i.d. isotropic Gaussian projection (Lemma A.2)
* ``srht``        -- Subsampled Randomized Hadamard Transform (Lemma A.1),
                     realized with a fast Walsh--Hadamard transform (FWHT)
* ``countsketch`` -- Count-Sketch (Lemma A.3)
* ``none``        -- identity (the uncompressed "ambient dimension" baseline)

All operators satisfy the paper's three Properties:

1. Linearity:            sk(a v + b w) = a sk(v) + b sk(w)   (exact)
2. Unbiasedness:         E[desk(sk(v))] = v                  (over the seed)
3. Bounded vector products (high-probability JL-style inner products)

Sketching is applied **per tensor** ("per-tensor" mode): each parameter
tensor of size n gets its own sketch of size b = clip(ceil(n * ratio)).
Per-tensor sketching keeps sk/desk shard-local under tensor parallelism
(zero extra collectives) and is the layer-wise variant the paper's
conclusion points to.  A ``concat`` mode (sketching the concatenated
d-vector, exactly the paper's Algorithm 1) is also provided for parity
experiments on small models.

Seeds: one PRNG key per round, shared by all clients (paper Remark 3.1);
per-tensor keys are derived with ``jax.random.fold_in`` on the leaf index,
so the same round key on every device/client reproduces the same operator.

This module is the per-leaf REFERENCE implementation: ``sketch_tree`` /
``desketch_tree`` loop over leaves and re-derive the operator on each side
of the round trip.  The hot path is the packed engine in
``repro.core.packed`` (one fused dispatch per round, operator derived once
and shared by sk/desk); tests/test_packed.py pins the two to exact parity.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class SketchConfig:
    """Configuration of the sketching compressor."""

    kind: str = "countsketch"  # none | gaussian | srht | countsketch
    ratio: float = 0.01        # b = ceil(n * ratio) per tensor
    min_b: int = 64            # floor on per-tensor sketch size
    max_b: Optional[int] = None
    mode: str = "per_tensor"   # per_tensor | concat
    transport_dtype: Any = jnp.float32  # dtype of the transmitted sketch
    use_pallas: bool = False   # route hot loops through Pallas kernels
    gaussian_chunk: int = 8192  # column chunk for on-the-fly Gaussian R
    # Count-sketch hash family (DESIGN.md §4):
    #   "balanced"    -- block-sparse JL: pad to (m, b) rows, random per-row
    #                    rotation, sum rows.  Collision prob is 0 within a
    #                    row and exactly 1/b across rows, so Lemma A.3's
    #                    variance bound carries; sk/desk are pure
    #                    gather/reshape/sum (no scatter) -- the fast family.
    #   "independent" -- classic per-element uniform hash + segment-sum
    #                    (the seed reference implementation).
    cs_hash: str = "balanced"

    def __post_init__(self):
        if self.kind not in ("none", "gaussian", "srht", "countsketch"):
            raise ValueError(f"unknown sketch kind: {self.kind}")
        if self.mode not in ("per_tensor", "concat"):
            raise ValueError(f"unknown sketch mode: {self.mode}")
        if not (self.kind == "none" or 0.0 < self.ratio <= 1.0):
            raise ValueError("ratio must be in (0, 1]")
        if self.cs_hash not in ("balanced", "independent"):
            raise ValueError(f"unknown cs_hash family: {self.cs_hash}")


def leaf_sketch_size(n: int, cfg: SketchConfig) -> int:
    """Sketch size for a tensor with n elements."""
    if cfg.kind == "none":
        return n
    b = max(cfg.min_b, int(math.ceil(n * cfg.ratio)))
    if cfg.max_b is not None:
        b = min(b, cfg.max_b)
    return min(b, n)


# ---------------------------------------------------------------------------
# Fast Walsh-Hadamard transform (pure jnp; Pallas version in kernels/fwht.py)
# ---------------------------------------------------------------------------

def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def fwht(x: jax.Array) -> jax.Array:
    """Unnormalized FWHT along the last axis (length must be a power of 2).

    Python loop over log2(n) butterflies -> unrolled into O(log n) HLO ops.
    """
    n = x.shape[-1]
    assert n & (n - 1) == 0, "FWHT length must be a power of 2"
    lead = x.shape[:-1]
    h = 1
    while h < n:
        x = x.reshape(lead + (n // (2 * h), 2, h))
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.concatenate([a + b, a - b], axis=-1)
        x = x.reshape(lead + (n,))
        # Note: concatenate([a+b, a-b]) along the paired axis reproduces the
        # standard butterfly once we track the (pairs, 2, h) layout.
        h *= 2
    return x


# The reshape trick above needs care: we keep a reference implementation
# that is obviously correct and use it to cross-check in tests.
def fwht_reference(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64).copy()
    n = x.shape[-1]
    h = 1
    while h < n:
        for i in range(0, n, h * 2):
            for j in range(i, i + h):
                a, b = x[..., j].copy(), x[..., j + h].copy()
                x[..., j] = a + b
                x[..., j + h] = a - b
        h *= 2
    return x


# ---------------------------------------------------------------------------
# Per-leaf sk / desk
# ---------------------------------------------------------------------------

def _keys(key: jax.Array, *tags: int) -> jax.Array:
    for t in tags:
        key = jax.random.fold_in(key, t)
    return key


def _gaussian_sk(cfg: SketchConfig, key: jax.Array, v: jax.Array, b: int) -> jax.Array:
    """sk(v) = R v / sqrt(b), R ~ N(0,1)^{b x n}, generated chunk-wise."""
    n = v.shape[0]
    c = cfg.gaussian_chunk
    n_pad = ((n + c - 1) // c) * c
    vp = jnp.pad(v, (0, n_pad - n)).reshape(n_pad // c, c)

    def body(acc, args):
        i, vc = args
        r = jax.random.normal(jax.random.fold_in(key, i), (c, b), dtype=v.dtype)
        return acc + vc @ r, None

    acc0 = jnp.zeros((b,), dtype=v.dtype)
    idx = jnp.arange(n_pad // c, dtype=jnp.int32)
    acc, _ = jax.lax.scan(body, acc0, (idx, vp))
    return acc / jnp.sqrt(jnp.asarray(b, v.dtype))


def _gaussian_desk(cfg: SketchConfig, key: jax.Array, s: jax.Array, n: int) -> jax.Array:
    """desk(s) = R^T s / sqrt(b) (so desk(sk(v)) = R^T R v / b, unbiased)."""
    b = s.shape[0]
    c = cfg.gaussian_chunk
    n_pad = ((n + c - 1) // c) * c

    def body(_, i):
        r = jax.random.normal(jax.random.fold_in(key, i), (c, b), dtype=s.dtype)
        return None, r @ s

    idx = jnp.arange(n_pad // c, dtype=jnp.int32)
    _, chunks = jax.lax.scan(body, None, idx)
    out = chunks.reshape(n_pad) / jnp.sqrt(jnp.asarray(b, s.dtype))
    return out[:n]


def _srht_params(key: jax.Array, n: int, b: int):
    n2 = next_pow2(n)
    sign_key, idx_key = jax.random.split(key)
    signs = jax.random.rademacher(sign_key, (n2,), dtype=jnp.float32)
    idx = jax.random.randint(idx_key, (b,), 0, n2)
    return n2, signs, idx


def _srht_sk(cfg: SketchConfig, key: jax.Array, v: jax.Array, b: int) -> jax.Array:
    n = v.shape[0]
    n2, signs, idx = _srht_params(key, n, b)
    vp = jnp.pad(v, (0, n2 - n)) * signs.astype(v.dtype)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        u = kops.fwht(vp) / jnp.sqrt(jnp.asarray(n2, v.dtype))
    else:
        u = fwht(vp) / jnp.sqrt(jnp.asarray(n2, v.dtype))
    scale = jnp.sqrt(jnp.asarray(n2 / b, v.dtype))
    return u[idx] * scale


def _srht_desk(cfg: SketchConfig, key: jax.Array, s: jax.Array, n: int) -> jax.Array:
    b = s.shape[0]
    n2, signs, idx = _srht_params(key, n, b)
    scale = jnp.sqrt(jnp.asarray(n2 / b, s.dtype))
    u = jnp.zeros((n2,), dtype=s.dtype).at[idx].add(s * scale)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        w = kops.fwht(u) / jnp.sqrt(jnp.asarray(n2, s.dtype))
    else:
        w = fwht(u) / jnp.sqrt(jnp.asarray(n2, s.dtype))
    return (w * signs.astype(s.dtype))[:n]


def _cs_hashes(key: jax.Array, n: int, b: int):
    hkey, skey = jax.random.split(key)
    h = jax.random.randint(hkey, (n,), 0, b)
    s = jax.random.rademacher(skey, (n,), dtype=jnp.float32)
    return h, s


def _balanced_cs_params(key: jax.Array, n: int, b: int):
    """Balanced (block-sparse JL) count-sketch: m = ceil(n/b) rows of b
    columns; row k is rotated by a uniform r_k, so element i = (k, c) hashes
    to slot (c + r_k) mod b.  Within a row no two elements collide; across
    rows any pair collides with probability exactly 1/b."""
    m = -(-n // b)
    rkey, skey = jax.random.split(key)
    r = jax.random.randint(rkey, (m,), 0, b)
    s = jax.random.rademacher(skey, (n,), dtype=jnp.float32)
    return r, s


def _balanced_sk_core(v: jax.Array, r: jax.Array, s: jax.Array, b: int) -> jax.Array:
    """sk given derived (r, s): out[j] = sum_k x[k, (j - r_k) mod b] --
    scatter-free gather + row-sum.  Shared by the per-leaf reference and the
    packed engine (single source of truth for the index math)."""
    n = v.shape[0]
    m = r.shape[0]
    x = jnp.pad(v * s.astype(v.dtype), (0, m * b - n)).reshape(m, b)
    idx = (jnp.arange(b)[None, :] - r[:, None]) % b
    return jnp.take_along_axis(x, idx, axis=1).sum(axis=0)


def _balanced_desk_core(u: jax.Array, r: jax.Array, s: jax.Array, n: int) -> jax.Array:
    """desk given derived (r, s): element (k, c) reads slot (c + r_k) mod b."""
    b = u.shape[0]
    idx = (jnp.arange(b)[None, :] + r[:, None]) % b
    return u[idx].reshape(-1)[:n] * s.astype(u.dtype)


def _balanced_cs_sk(cfg: SketchConfig, key: jax.Array, v: jax.Array, b: int) -> jax.Array:
    r, s = _balanced_cs_params(key, v.shape[0], b)
    return _balanced_sk_core(v, r, s, b)


def _balanced_cs_desk(cfg: SketchConfig, key: jax.Array, u: jax.Array, n: int) -> jax.Array:
    r, s = _balanced_cs_params(key, n, u.shape[0])
    return _balanced_desk_core(u, r, s, n)


def _countsketch_sk(cfg: SketchConfig, key: jax.Array, v: jax.Array, b: int) -> jax.Array:
    if cfg.cs_hash == "balanced":
        return _balanced_cs_sk(cfg, key, v, b)
    n = v.shape[0]
    h, s = _cs_hashes(key, n, b)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        return kops.countsketch(v * s.astype(v.dtype), h, b)
    return jax.ops.segment_sum(v * s.astype(v.dtype), h, num_segments=b)


def _countsketch_desk(cfg: SketchConfig, key: jax.Array, u: jax.Array, n: int) -> jax.Array:
    if cfg.cs_hash == "balanced":
        return _balanced_cs_desk(cfg, key, u, n)
    b = u.shape[0]
    h, s = _cs_hashes(key, n, b)
    return u[h] * s.astype(u.dtype)


def sk_leaf(cfg: SketchConfig, key: jax.Array, v: jax.Array) -> jax.Array:
    """Sketch one flat vector v -> (b,). (paper: bar_m^c = sk(delta))."""
    assert v.ndim == 1
    n = v.shape[0]
    if cfg.kind == "none":
        return v.astype(cfg.transport_dtype)
    b = leaf_sketch_size(n, cfg)
    if b >= n:  # sketch would not compress; transmit raw (still linear/unbiased)
        return v.astype(cfg.transport_dtype)
    fn = {"gaussian": _gaussian_sk, "srht": _srht_sk, "countsketch": _countsketch_sk}[cfg.kind]
    return fn(cfg, key, v, b).astype(cfg.transport_dtype)


def desk_leaf(cfg: SketchConfig, key: jax.Array, s: jax.Array, n: int,
              dtype=jnp.float32) -> jax.Array:
    """Desketch (b,) -> flat (n,). (paper: desk(bar_m))."""
    s = s.astype(dtype)
    if cfg.kind == "none" or s.shape[0] >= n:
        return s[:n]
    fn = {"gaussian": _gaussian_desk, "srht": _srht_desk, "countsketch": _countsketch_desk}[cfg.kind]
    return fn(cfg, key, s, n)


SKETCH_CHUNK_NUMEL = 1 << 24    # leaves above this sketch per layer slice


def sk_leaf_stacked(cfg: SketchConfig, key: jax.Array,
                    rows: jax.Array) -> jax.Array:
    """sk each row of ``rows`` (L, n) with the per-row operator
    ``fold_in(key, j)`` -- the layer-wise chunked path for leaves whose flat
    size would make one hash/sign temporary too large.  ``lax.map`` bounds
    the temporaries to one row's worth and realizes the layer-wise sketching
    the paper's conclusion proposes (shared by the mesh round's per-leaf
    reference path in ``launch.train``)."""
    def sk_one(args):
        j, v = args
        return sk_leaf(cfg, jax.random.fold_in(key, j), v)
    return jax.lax.map(sk_one, (jnp.arange(rows.shape[0]), rows))


def desk_leaf_stacked(cfg: SketchConfig, key: jax.Array, s: jax.Array,
                      n: int) -> jax.Array:
    """Row-wise desk of ``s`` (L, b) back to (L, n): the adjoint of
    ``sk_leaf_stacked`` under the same per-row ``fold_in(key, j)`` chain."""
    def desk_one(args):
        j, sj = args
        return desk_leaf(cfg, jax.random.fold_in(key, j), sj, n)
    return jax.lax.map(desk_one, (jnp.arange(s.shape[0]), s))


# ---------------------------------------------------------------------------
# Pytree-level sketching
# ---------------------------------------------------------------------------

def tree_sketch_sizes(cfg: SketchConfig, tree: Pytree) -> list[int]:
    leaves = jax.tree_util.tree_leaves(tree)
    return [leaf_sketch_size(int(np.prod(l.shape)) if l.shape else 1, cfg) for l in leaves]


def total_sketch_bits(cfg: SketchConfig, tree: Pytree) -> int:
    """Uplink payload in bits per round (the paper's per-round cost O(b)).

    Routed through the packing plan so the count is exactly the transmitted
    ``(b_total,)`` payload (matches the per-leaf sum in per_tensor mode and
    the single concatenated sketch in concat mode)."""
    from repro.core.packed import make_packing_plan
    itemsize = jnp.dtype(cfg.transport_dtype).itemsize
    return make_packing_plan(cfg, tree).b_total * itemsize * 8


def sketch_tree(cfg: SketchConfig, key: jax.Array, tree: Pytree) -> Pytree:
    """sk over every leaf (per_tensor) or over the concatenation (concat)."""
    if cfg.mode == "concat":
        leaves, _ = jax.tree_util.tree_flatten(tree)
        flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
        return sk_leaf(cfg, key, flat)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = [sk_leaf(cfg, _keys(key, i), l.reshape(-1).astype(jnp.float32))
           for i, l in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def desketch_tree(cfg: SketchConfig, key: jax.Array, sketches: Pytree,
                  like: Pytree) -> Pytree:
    """desk back to the shapes/dtypes of ``like``."""
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    if cfg.mode == "concat":
        sizes = [int(np.prod(l.shape)) if l.shape else 1 for l in like_leaves]
        flat = desk_leaf(cfg, key, sketches, sum(sizes))
        parts = []
        off = 0
        for l, n in zip(like_leaves, sizes):
            parts.append(flat[off:off + n].reshape(l.shape).astype(l.dtype))
            off += n
        return jax.tree_util.tree_unflatten(treedef, parts)
    sk_leaves = jax.tree_util.tree_leaves(sketches)
    out = []
    for i, (l, s) in enumerate(zip(like_leaves, sk_leaves)):
        n = int(np.prod(l.shape)) if l.shape else 1
        v = desk_leaf(cfg, _keys(key, i), s, n).reshape(l.shape).astype(l.dtype)
        out.append(v)
    return jax.tree_util.tree_unflatten(treedef, out)


def roundtrip_tree(cfg: SketchConfig, key: jax.Array, tree: Pytree) -> Pytree:
    """desk(sk(tree)) -- the lossy replicate the server optimizer consumes."""
    return desketch_tree(cfg, key, sketch_tree(cfg, key, tree), tree)
