"""Packed sketch engine: one fused dispatch per round instead of a per-leaf loop.

The per-leaf path in ``repro.core.sketch`` (kept as the reference
implementation) runs a Python loop over every parameter leaf and re-derives
the CountSketch hashes/signs (or SRHT params, or Gaussian chunk keys) from
scratch for sk *and again* for desk.  FetchSGD (Rothchild et al. 2020) and
FedSKETCH (Haddadpour et al. 2020) instead sketch the *concatenated*
gradient into one contiguous buffer, making compression a single fused
memory-bound pass.  This module adopts that design (DESIGN.md §4):

* ``PackingPlan``        -- static layout, computed ONCE from the param
                            pytree + ``SketchConfig``: every leaf's flat
                            vector gets a slice of one contiguous
                            ``(d_total,)`` buffer and every leaf's sketch a
                            slice of one contiguous ``(b_total,)`` payload.
* ``derive_round_params``-- per-round hashes/signs/SRHT params/Gaussian
                            keys derived ONCE per (round, leaf) and shared
                            by sk and desk.  Leaves with identical (n, b)
                            are derived with a single vmapped PRNG call
                            (bit-identical to the per-leaf calls: threefry
                            streams depend only on the folded key).
* ``sk_packed``/``desk_packed`` -- fused single-jitted-pass sk/desk for all
                            three sketch families.  The default balanced
                            count-sketch family is pure gather/reshape/sum
                            (scatter-free; XLA-optimal, no kernel needed).
                            The "independent" family collapses the whole
                            tree to ONE segment-sum over a global hash
                            (leaf-local slot + payload offset); with
                            ``use_pallas`` its multi-client sk is ONE
                            Pallas launch over a (client, b-block, tile)
                            grid instead of O(G x num_leaves) kernel calls.

Per-leaf key derivation matches ``sketch_tree`` exactly (fold_in on the
leaf index), so packed and per-leaf paths produce identical sketches --
parity is enforced by tests/test_packed.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketch import (SketchConfig, _balanced_cs_params,
                               _balanced_desk_core, _balanced_sk_core,
                               _cs_hashes, _gaussian_desk, _gaussian_sk,
                               _keys, _srht_params, fwht, leaf_sketch_size,
                               next_pow2)

Pytree = Any


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Static layout of one pytree leaf inside the packed (d_total,) buffer."""
    shape: tuple[int, ...]
    dtype: Any
    n: int
    in_off: int


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One sketch unit: a leaf (per_tensor mode) or the whole packed vector
    (concat mode).  ``raw`` units are transmitted uncompressed (b == n)."""
    index: int                 # position in op/payload order
    in_off: int                # offset into the packed input buffer
    n: int                     # input length
    b: int                     # payload slots (== n when raw)
    pay_off: int               # offset into the packed payload
    raw: bool
    tag: Optional[int]         # fold_in tag (leaf index); None -> round key
    n2: int                    # next_pow2(n), used by srht


@dataclasses.dataclass(frozen=True)
class PackingPlan:
    """Static packing of a param pytree under one SketchConfig.

    Computed once (shapes only -- safe to build inside a jit trace); shared
    by every round.  ``b_total`` is the uplink payload length in slots.
    """
    cfg: SketchConfig
    treedef: Any
    leaves: tuple[LeafSpec, ...]
    ops: tuple[OpSpec, ...]
    d_total: int
    b_total: int

    @property
    def all_raw(self) -> bool:
        return all(op.raw for op in self.ops)


def shard_local_abstract(tree: Pytree, pspecs: Pytree,
                         axis_sizes) -> Pytree:
    """Per-device local shard shapes of ``tree`` under ``pspecs``.

    ``axis_sizes`` maps mesh axis name -> size (``dict(mesh.shape)``).
    Returns ``ShapeDtypeStruct`` leaves whose dim i is the global dim divided
    by the product of the mesh axes sharding it -- the leaf shapes a
    ``shard_map`` body sees.  Every sharded dim must divide evenly (the same
    precondition shard_map itself enforces)."""
    def local(leaf, spec):
        dims = []
        # a spec may be shorter than the leaf rank (trailing dims implicitly
        # replicated): pad with None so no dim is silently dropped
        spec = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
        for d, e in zip(leaf.shape, spec):
            axes = () if e is None else (e if isinstance(e, tuple) else (e,))
            sz = 1
            for a in axes:
                sz *= axis_sizes[a]
            if d % sz:
                raise ValueError(
                    f"dim {d} of {leaf.shape} not divisible by mesh axes "
                    f"{axes} (size {sz})")
            dims.append(d // sz)
        return jax.ShapeDtypeStruct(tuple(dims), leaf.dtype)
    return jax.tree.map(local, tree, pspecs)


def make_sharded_packing_plan(cfg: SketchConfig, tree: Pytree, pspecs: Pytree,
                              axis_sizes) -> PackingPlan:
    """PackingPlan over the SHARD-LOCAL slices of ``tree`` (DESIGN §8).

    The mesh round sketches each leaf's local shard inside ``shard_map``
    (shard-local along the model/FSDP axes -- no all-gather of the d-dim
    delta), so the packed layout must be computed from the *local* shapes,
    not the global ones.  Built once outside any trace; per-leaf fold_in
    tags match the per-leaf reference path in
    ``launch.train.sharded_sketch_avg_desk`` exactly."""
    return make_packing_plan(cfg, shard_local_abstract(tree, pspecs,
                                                       axis_sizes))


def make_packing_plan(cfg: SketchConfig, tree: Pytree) -> PackingPlan:
    """Lay out every leaf of ``tree`` into the packed input/payload buffers."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    leaves, in_off = [], 0
    for l in flat:
        n = int(np.prod(l.shape)) if l.shape else 1
        leaves.append(LeafSpec(tuple(l.shape), l.dtype, n, in_off))
        in_off += n
    d_total = in_off

    ops, pay_off = [], 0
    if cfg.mode == "concat":
        b = d_total if cfg.kind == "none" else leaf_sketch_size(d_total, cfg)
        ops.append(OpSpec(0, 0, d_total, b, 0, b >= d_total, None,
                          next_pow2(d_total)))
        pay_off = b
    else:
        for i, spec in enumerate(leaves):
            n = spec.n
            b = n if cfg.kind == "none" else leaf_sketch_size(n, cfg)
            ops.append(OpSpec(i, spec.in_off, n, b, pay_off, b >= n, i,
                              next_pow2(n)))
            pay_off += b
    return PackingPlan(cfg, treedef, tuple(leaves), tuple(ops),
                       d_total, pay_off)


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

def pack_tree(plan: PackingPlan, tree: Pytree) -> jax.Array:
    """Flatten ``tree`` into the contiguous f32 (d_total,) buffer."""
    flat = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32) for l in flat])


def unpack_tree(plan: PackingPlan, flat: jax.Array, cast: bool = True) -> Pytree:
    """Slice the (d_total,) buffer back into leaf shapes (plan dtypes)."""
    out = []
    for spec in plan.leaves:
        v = flat[spec.in_off:spec.in_off + spec.n].reshape(spec.shape)
        out.append(v.astype(spec.dtype) if cast else v)
    return jax.tree_util.tree_unflatten(plan.treedef, out)


# ---------------------------------------------------------------------------
# per-round operator parameters (derived once, shared by sk and desk)
# ---------------------------------------------------------------------------

def _group_derive(key: jax.Array, group: list[OpSpec], fn):
    """Derive ``fn(key_op, n, b)`` for every op of an (n, b) group with ONE
    vmapped PRNG call -- bit-identical to the per-leaf fold_in chain
    (threefry streams depend only on the folded key).  Returns results with
    a leading group axis."""
    n, b = group[0].n, group[0].b
    if len(group) == 1 and group[0].tag is None:  # concat mode: round key
        return jax.tree.map(lambda x: x[None], fn(key, n, b))
    tags = jnp.asarray([op.tag for op in group], jnp.int32)
    ks = jax.vmap(lambda t: _keys(key, t))(tags)
    return jax.vmap(lambda k: fn(k, n, b))(ks)


def _grouped(ops) -> dict[tuple[int, int], list[OpSpec]]:
    groups: dict[tuple[int, int], list[OpSpec]] = {}
    for op in ops:
        if not op.raw:
            groups.setdefault((op.n, op.b), []).append(op)
    return groups


def derive_generation_params(plan: PackingPlan, base_key: jax.Array,
                             g: jax.Array) -> dict:
    """Re-derive generation round ``g``'s sketch operator from the run's
    base key: ``derive_round_params(plan, fold_in(base_key, g))``.

    This is the contract the async staleness buffers depend on (DESIGN §7):
    a delayed payload sketched in round g can only be desketched with round
    g's OWN operator (Property 1 linearity holds within one operator), and
    because every round key is ``fold_in(base_key, t)``, the operator is
    recomputable at pop time from ``(base_key, g)`` alone -- nothing but the
    payload needs storing.  Single source of the fold, shared by
    ``fed.async_buffer.make_async_round`` and the mesh ring buffer
    (``launch/train.py``)."""
    return derive_round_params(plan, jax.random.fold_in(base_key, g))


def derive_round_params(plan: PackingPlan, key: jax.Array) -> dict:
    """Derive the round's sketch operator ONCE.

    The returned dict is consumed by both ``sk_packed`` and ``desk_packed``,
    so hashes/signs/SRHT params exist exactly once per (round, leaf) -- the
    per-leaf path re-derives them on each side of the round trip.
    """
    cfg = plan.cfg
    if cfg.kind == "none" or plan.all_raw:
        return {}

    if cfg.kind == "countsketch":
        if cfg.cs_hash == "balanced":
            params: list = [None] * len(plan.ops)
            for group in _grouped(plan.ops).values():
                rs, ss = _group_derive(key, group, _balanced_cs_params)
                for r, op in enumerate(group):
                    params[op.index] = (rs[r], ss[r])
            return {"bal": tuple(params)}
        h_parts: list = [None] * len(plan.ops)
        s_parts: list = [None] * len(plan.ops)
        for group in _grouped(plan.ops).values():
            hs, ss = _group_derive(key, group, _cs_hashes)
            for r, op in enumerate(group):
                h_parts[op.index] = hs[r] + op.pay_off
                s_parts[op.index] = ss[r]
        for op in plan.ops:
            if op.raw:
                h_parts[op.index] = op.pay_off + jnp.arange(op.n, dtype=jnp.int32)
                s_parts[op.index] = jnp.ones((op.n,), jnp.float32)
        return {"h": jnp.concatenate(h_parts), "s": jnp.concatenate(s_parts)}

    if cfg.kind == "srht":
        params: list = [None] * len(plan.ops)
        for group in _grouped(plan.ops).values():
            signs, idx = _group_derive(key, group,
                                       lambda k, n, b: _srht_params(k, n, b)[1:])
            for r, op in enumerate(group):
                params[op.index] = (signs[r], idx[r])
        return {"srht": tuple(params)}

    if cfg.kind == "gaussian":
        keys: list = [None] * len(plan.ops)
        for op in plan.ops:
            if not op.raw:
                keys[op.index] = key if op.tag is None else _keys(key, op.tag)
        return {"keys": tuple(keys)}

    raise ValueError(f"unknown sketch kind: {cfg.kind}")


# ---------------------------------------------------------------------------
# fused sk / desk over the packed buffers
# ---------------------------------------------------------------------------

def _srht_groups(plan: PackingPlan) -> dict[int, list[OpSpec]]:
    """Non-raw ops grouped by padded FWHT length (batched transform rows)."""
    groups: dict[int, list[OpSpec]] = {}
    for op in plan.ops:
        if not op.raw:
            groups.setdefault(op.n2, []).append(op)
    return groups


def _batched_fwht(cfg: SketchConfig, rows: jax.Array) -> jax.Array:
    """FWHT along the last axis of (..., L, n2) rows; Pallas when routed."""
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        lead = rows.shape[:-1]
        out = kops.fwht_rows(rows.reshape(-1, rows.shape[-1]))
        return out.reshape(lead + (rows.shape[-1],))
    return fwht(rows)


def sk_flat(plan: PackingPlan, rp: dict, flat: jax.Array) -> jax.Array:
    """Fused sk of the packed (d_total,) buffer -> (b_total,) payload."""
    cfg = plan.cfg
    if cfg.kind == "none" or plan.all_raw:
        return flat.astype(cfg.transport_dtype)

    if cfg.kind == "countsketch":
        if cfg.cs_hash == "balanced":
            parts: list = [None] * len(plan.ops)
            for op in plan.ops:
                v = flat[op.in_off:op.in_off + op.n]
                if op.raw:
                    parts[op.index] = v
                    continue
                r, s = rp["bal"][op.index]
                parts[op.index] = _balanced_sk_core(v, r, s, op.b)
            return jnp.concatenate(parts).astype(cfg.transport_dtype)
        x = flat * rp["s"]
        if cfg.use_pallas:
            from repro.kernels import ops as kops
            out = kops.countsketch(x, rp["h"], plan.b_total)
        else:
            out = jax.ops.segment_sum(x, rp["h"], num_segments=plan.b_total)
        return out.astype(cfg.transport_dtype)

    if cfg.kind == "srht":
        parts: list = [None] * len(plan.ops)
        for n2, group in _srht_groups(plan).items():
            rows = jnp.stack([
                jnp.pad(flat[op.in_off:op.in_off + op.n], (0, n2 - op.n))
                * rp["srht"][op.index][0] for op in group])
            u = _batched_fwht(cfg, rows) / jnp.sqrt(jnp.asarray(n2, jnp.float32))
            for r, op in enumerate(group):
                scale = jnp.sqrt(jnp.asarray(n2 / op.b, jnp.float32))
                parts[op.index] = u[r][rp["srht"][op.index][1]] * scale
        for op in plan.ops:
            if op.raw:
                parts[op.index] = flat[op.in_off:op.in_off + op.n]
        return jnp.concatenate(parts).astype(cfg.transport_dtype)

    if cfg.kind == "gaussian":
        parts = [None] * len(plan.ops)
        for op in plan.ops:
            v = flat[op.in_off:op.in_off + op.n]
            parts[op.index] = v if op.raw else _gaussian_sk(
                cfg, rp["keys"][op.index], v, op.b)
        return jnp.concatenate(parts).astype(cfg.transport_dtype)

    raise ValueError(f"unknown sketch kind: {cfg.kind}")


def desk_flat(plan: PackingPlan, rp: dict, payload: jax.Array) -> jax.Array:
    """Fused desk of the (b_total,) payload -> packed (d_total,) buffer."""
    cfg = plan.cfg
    s = payload.astype(jnp.float32)
    if cfg.kind == "none" or plan.all_raw:
        return s

    if cfg.kind == "countsketch":
        if cfg.cs_hash == "balanced":
            parts: list = [None] * len(plan.ops)
            for op in plan.ops:
                u = s[op.pay_off:op.pay_off + op.b]
                if op.raw:
                    parts[op.index] = u
                    continue
                r, sg = rp["bal"][op.index]
                parts[op.index] = _balanced_desk_core(u, r, sg, op.n)
            return jnp.concatenate(parts)
        return s[rp["h"]] * rp["s"]

    if cfg.kind == "srht":
        parts: list = [None] * len(plan.ops)
        for n2, group in _srht_groups(plan).items():
            rows = []
            for op in group:
                signs, idx = rp["srht"][op.index]
                scale = jnp.sqrt(jnp.asarray(n2 / op.b, jnp.float32))
                rows.append(jnp.zeros((n2,), jnp.float32).at[idx].add(
                    s[op.pay_off:op.pay_off + op.b] * scale))
            w = _batched_fwht(cfg, jnp.stack(rows)) \
                / jnp.sqrt(jnp.asarray(n2, jnp.float32))
            for r, op in enumerate(group):
                signs = rp["srht"][op.index][0]
                parts[op.index] = (w[r] * signs)[:op.n]
        for op in plan.ops:
            if op.raw:
                parts[op.index] = s[op.pay_off:op.pay_off + op.b]
        return jnp.concatenate(parts)

    if cfg.kind == "gaussian":
        parts = [None] * len(plan.ops)
        for op in plan.ops:
            u = s[op.pay_off:op.pay_off + op.b]
            parts[op.index] = u if op.raw else _gaussian_desk(
                cfg, rp["keys"][op.index], u, op.n)
        return jnp.concatenate(parts)

    raise ValueError(f"unknown sketch kind: {cfg.kind}")


# ---------------------------------------------------------------------------
# pytree-level entry points
# ---------------------------------------------------------------------------

def sk_packed(plan: PackingPlan, rp: dict, tree: Pytree) -> jax.Array:
    """Sketch a whole pytree in one fused pass -> (b_total,) payload."""
    return sk_flat(plan, rp, pack_tree(plan, tree))


def desk_packed(plan: PackingPlan, rp: dict, payload: jax.Array) -> Pytree:
    """Desketch the (b_total,) payload back to the plan's pytree."""
    return unpack_tree(plan, desk_flat(plan, rp, payload))


def sk_packed_clients(plan: PackingPlan, rp: dict, stacked: Pytree) -> jax.Array:
    """Sketch G stacked client trees (leaves (G, ...)) -> (G, b_total).

    For the independent-hash CountSketch family with ``use_pallas`` this is
    ONE batched Pallas launch over a (client, b-block, tile) grid; all
    other families (including the default balanced one, which is
    scatter-free and needs no kernel) run as a vmap of the fused pass
    (still one jitted dispatch for the whole tree, not per leaf).
    """
    cfg = plan.cfg
    flat2 = jax.vmap(lambda t: pack_tree(plan, t))(stacked)
    if (cfg.kind == "countsketch" and cfg.cs_hash == "independent"
            and cfg.use_pallas and not plan.all_raw):
        from repro.kernels import ops as kops
        out = kops.countsketch_clients(flat2 * rp["s"][None, :], rp["h"],
                                       plan.b_total)
        return out.astype(cfg.transport_dtype)
    return jax.vmap(lambda f: sk_flat(plan, rp, f))(flat2)


def sk_packed_clients_wsum(plan: PackingPlan, rp: dict, stacked: Pytree,
                           w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused sketch of a client chunk, reduced to its weighted payload sum.

    The streaming unit of work of the microbatch fold (DESIGN.md §12):
    sketch the ``mb`` stacked client trees (leaves ``(mb, ...)``) with the
    shared round operator and immediately reduce them to the ``(b_total,)``
    weighted payload sum plus the scalar weight sum, so no ``(G, b_total)``
    payload ever materializes outside one chunk.  Linearity (Property 1)
    makes the chunk-summed sketch exactly the sketch of the weighted delta
    sum, so folding these partial sums over chunks -- and then psumming
    across mesh client shards -- reproduces the cohort mean aggregation.
    """
    s = sk_packed_clients(plan, rp, stacked).astype(jnp.float32)
    return jnp.sum(s * w[:, None].astype(s.dtype), axis=0), jnp.sum(w)


def roundtrip_packed(plan: PackingPlan, key: jax.Array, tree: Pytree) -> Pytree:
    """desk(sk(tree)) with round params derived exactly once."""
    rp = derive_round_params(plan, key)
    return desk_packed(plan, rp, sk_packed(plan, rp, tree))
