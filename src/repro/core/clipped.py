"""Clipped SAFL for heavy-tailed client noise (paper §2 "Noise in Deep
Learning" + Conclusion: the paper proposes adaptive algorithms for BOTH the
mild-noise and heavy-tailed settings; cf. Chezhegov et al. 2024 — AdaGrad
can fail under heavy tails unless combined with clipping).

Mechanism: each client clips its local model delta to an l2 ball of radius
``tau`` BEFORE sketching.  Clipping commutes safely with the rest of
Algorithm 1 because it acts on the true delta (pre-compression), so the
sketch properties (linearity over the averaged *clipped* deltas,
unbiasedness of desk∘sk) are untouched; the server ADA_OPT step is
unchanged.  Under sub-Gaussian noise (tau -> inf) this reduces exactly to
SAFL."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.adaptive import apply_update
from repro.core.packed import (derive_round_params, desk_packed,
                               make_packing_plan, sk_packed_clients)
from repro.core.safl import (SAFLConfig, client_delta, masked_mean,
                             masked_where_tree, resolve_microbatch,
                             streamed_sketch_round)

Pytree = Any
LossFn = Callable[[Pytree, Any], jax.Array]


@dataclasses.dataclass(frozen=True)
class ClippedSAFLConfig:
    base: SAFLConfig = SAFLConfig()
    clip_tau: float = 1.0          # l2 radius for the client delta
    per_tensor: bool = False       # clip each tensor separately vs globally


def clip_delta(cfg: ClippedSAFLConfig, delta: Pytree) -> Pytree:
    """l2-clip a client delta (global norm by default)."""
    if cfg.per_tensor:
        def clip_one(x):
            nrm = jnp.sqrt(jnp.sum(x.astype(jnp.float32) ** 2) + 1e-12)
            return x * jnp.minimum(1.0, cfg.clip_tau / nrm)
        return jax.tree.map(clip_one, delta)
    sq = sum(jnp.sum(x.astype(jnp.float32) ** 2)
             for x in jax.tree.leaves(delta))
    scale = jnp.minimum(1.0, cfg.clip_tau / jnp.sqrt(sq + 1e-12))
    return jax.tree.map(lambda x: x * scale, delta)


def clip_trigger(cfg: ClippedSAFLConfig, delta: Pytree) -> jax.Array:
    """1.0 if this client's pre-clip delta exceeded the clip radius (under
    per-tensor clipping: if ANY tensor did) -- the ``clip_frac`` telemetry
    probe averages this over the cohort."""
    if cfg.per_tensor:
        trig = [jnp.sqrt(jnp.sum(x.astype(jnp.float32) ** 2) + 1e-12)
                > cfg.clip_tau for x in jax.tree.leaves(delta)]
        return jnp.any(jnp.stack(trig)).astype(jnp.float32)
    sq = sum(jnp.sum(x.astype(jnp.float32) ** 2)
             for x in jax.tree.leaves(delta))
    return (jnp.sqrt(sq + 1e-12) > cfg.clip_tau).astype(jnp.float32)


def clipped_safl_round(cfg: ClippedSAFLConfig, loss_fn: LossFn,
                       params: Pytree, opt_state: dict, batch: Pytree,
                       round_key: jax.Array, *,
                       plan=None, part_mask=None, fault_spec=None,
                       sentinel=None, telemetry=None,
                       microbatch=None,
                       codec=None) -> tuple[Pytree, dict, dict]:
    """One SAFL round with per-client delta clipping (heavy-tail defense).

    batch leaves: (G, K, mb, ...) as in safl_round; ``plan``/``part_mask``/
    ``fault_spec``/``sentinel``/``telemetry`` as in safl_round (plan built
    once by multi-round callers; the mask restricts the server mean to the
    sampled cohort; faults and sentinels fuse into it per DESIGN.md §10 --
    client clipping bounds honest heavy tails, the sentinel handles
    adversarially broken payloads, so SACFL composes both defenses).  With
    telemetry on, this round additionally supplies the ``clip_frac`` probe:
    the cohort fraction whose pre-clip delta norm exceeded tau.
    ``microbatch`` streams the aggregation over client chunks exactly as in
    ``safl_round`` (clipping is per-client and so commutes with the fold);
    None / >= G keeps the materialized path below untouched.  ``codec``
    quantizes the sketched (post-clip) uplink exactly as in ``safl_round``
    (DESIGN.md §13): clipping acts on the true delta before compression, so
    the codec composes with it the same way sketching does."""
    if codec is not None and telemetry is not None:
        raise ValueError(
            "telemetry probes read the bare server opt state; under "
            "codec.error_feedback the round state is the wrapped "
            "{'opt', 'ef'} dict -- run telemetry without a codec")
    base = cfg.base
    eta = jnp.asarray(base.client_lr, jnp.float32)

    if microbatch is not None:
        mb = resolve_microbatch(microbatch,
                                jax.tree.leaves(batch)[0].shape[0])
        if mb is not None:
            def clipped_client(b):
                delta, l = client_delta(base, loss_fn, params, b, eta)
                return clip_delta(cfg, delta), l
            return streamed_sketch_round(
                base, clipped_client, params, opt_state, batch, round_key,
                mb, plan=plan, part_mask=part_mask, fault_spec=fault_spec,
                sentinel=sentinel, telemetry=telemetry, codec=codec)

    ef_wrapped = codec is not None and codec.error_feedback
    opt_orig = opt_state
    ef = None
    if ef_wrapped:
        ef, opt_state = opt_orig["ef"], opt_orig["opt"]

    probe_clip = telemetry is not None and telemetry.clip

    # the trigger output only exists when its probe is on -- with telemetry
    # off the vmapped program is byte-identical to the pinned one
    if probe_clip:
        def one_client(mb):
            delta, l = client_delta(base, loss_fn, params, mb, eta)
            return clip_delta(cfg, delta), l, clip_trigger(cfg, delta)
        deltas, losses, triggers = jax.vmap(one_client)(batch)
    else:
        def one_client(mb):
            delta, l = client_delta(base, loss_fn, params, mb, eta)
            return clip_delta(cfg, delta), l
        deltas, losses = jax.vmap(one_client)(batch)
        triggers = None
    if plan is None:
        plan = make_packing_plan(base.sketch, params)
    rp = derive_round_params(plan, round_key)
    sketches = sk_packed_clients(plan, rp, deltas)
    if codec is not None:   # decode before corruption/vetting, DESIGN.md §13
        from repro.fed.codec import encode_decode
        sketches = sketches.astype(jnp.float32)
        if ef_wrapped:
            sketches, ef_new = encode_decode(codec, round_key, sketches,
                                             ef_rows=ef)
            ef = masked_where_tree(part_mask, ef_new, ef)
        else:
            sketches, _ = encode_decode(codec, round_key, sketches)
    counters = {}
    if fault_spec is not None or sentinel is not None:
        from repro.fed.robust import guard_uplink
        sketches, part_mask, counters = guard_uplink(
            sketches, part_mask, fault_spec, sentinel)
    mbar = masked_mean(sketches, part_mask)
    update = desk_packed(plan, rp, mbar)
    new_params, new_opt = apply_update(base.server, opt_state, params, update)
    if ef_wrapped:
        new_opt = {"opt": new_opt, "ef": ef}
    if codec is not None:
        from repro.fed.codec import measured_uplink_bits
        counters["uplink_bits"] = measured_uplink_bits(
            codec, plan.b_total, eff_mask=part_mask,
            num_clients=losses.shape[0])
    loss = masked_mean(losses, part_mask)
    if sentinel is not None:
        from repro.fed.robust import carry_if_empty, divergence_flag
        new_params, new_opt = carry_if_empty(
            part_mask, (new_params, new_opt), (params, opt_orig))
        counters = {**counters, "diverged": divergence_flag(sentinel, loss)}
    metrics = {"loss": loss, **counters}
    if telemetry is not None:
        from repro.obs.telemetry import telemetry_probes
        metrics.update(telemetry_probes(
            telemetry, deltas=deltas, update=update, part_mask=part_mask,
            state=new_opt,
            clip_frac=masked_mean(triggers, part_mask) if probe_clip
            else None))
    return new_params, new_opt, metrics
