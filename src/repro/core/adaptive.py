"""ADA_OPT: server-side adaptive optimizers (paper Algorithm 2).

The server consumes the desketched averaged client update ``u = desk(m̄_t)``
as a pseudo-gradient.  AMSGrad is the paper's analyzed instantiation
(Alg. 2); Adam is what the experiments use (§5); AdaGrad / SGD / SGDm round
out the family ("flexibility on the choice of adaptive optimizers").

All optimizers are pure pytree->pytree functions so they jit/shard cleanly;
state tensors inherit the sharding of the parameters they precondition.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdaConfig:
    name: str = "amsgrad"      # amsgrad | adam | adagrad | sgd | sgdm
    lr: float = 1e-2           # kappa in Alg. 2
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    bias_correction: bool = False  # Alg. 2 uses none; Adam-mode may enable
    weight_decay: float = 0.0
    moment_dtype: Any = jnp.float32  # bf16 option for mega-configs (DESIGN §2)

    def __post_init__(self):
        if self.name not in ("amsgrad", "adam", "adagrad", "sgd", "sgdm"):
            raise ValueError(f"unknown optimizer {self.name}")


def init_opt_state(cfg: AdaConfig, params: Pytree) -> dict:
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, cfg.moment_dtype), params)
    state = {"step": jnp.zeros((), jnp.int32)}
    if cfg.name in ("amsgrad", "adam", "sgdm"):
        state["m"] = zeros()
    if cfg.name in ("amsgrad", "adam", "adagrad"):
        state["v"] = zeros()
    if cfg.name == "amsgrad":
        state["vhat"] = zeros()
    return state


def apply_update(cfg: AdaConfig, state: dict, params: Pytree, update: Pytree,
                 lr_scale: jax.Array | float = 1.0) -> tuple[Pytree, dict]:
    """One ADA_OPT step.  ``update`` is the (pseudo-)gradient direction
    (for SAFL: desk(m̄_t) = desketched averaged local-delta, which already
    carries the client lr eta).  Returns (new_params, new_state)."""
    step = state["step"] + 1
    lr = cfg.lr * lr_scale
    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps
    md = cfg.moment_dtype

    u32 = jax.tree.map(lambda u: u.astype(jnp.float32), update)

    if cfg.name == "sgd":
        direction = u32
        new_state = {"step": step}
    elif cfg.name == "sgdm":
        m = jax.tree.map(lambda m, u: (b1 * m.astype(jnp.float32) + u).astype(md),
                         state["m"], u32)
        direction = jax.tree.map(lambda m: m.astype(jnp.float32), m)
        new_state = {"step": step, "m": m}
    elif cfg.name == "adagrad":
        v = jax.tree.map(lambda v, u: (v.astype(jnp.float32) + u * u).astype(md),
                         state["v"], u32)
        direction = jax.tree.map(
            lambda u, v: u / (jnp.sqrt(v.astype(jnp.float32)) + eps), u32, v)
        new_state = {"step": step, "v": v}
    else:  # adam / amsgrad (Alg. 2)
        m = jax.tree.map(lambda m, u: (b1 * m.astype(jnp.float32)
                                       + (1 - b1) * u).astype(md),
                         state["m"], u32)
        v = jax.tree.map(lambda v, u: (b2 * v.astype(jnp.float32)
                                       + (1 - b2) * u * u).astype(md),
                         state["v"], u32)
        new_state = {"step": step, "m": m, "v": v}
        if cfg.name == "amsgrad":
            vhat = jax.tree.map(lambda vh, v: jnp.maximum(vh, v), state["vhat"], v)
            new_state["vhat"] = vhat
            precond = vhat
        else:
            precond = v
        if cfg.bias_correction:
            c1 = 1 - b1 ** step.astype(jnp.float32)
            c2 = 1 - b2 ** step.astype(jnp.float32)
        else:
            c1 = c2 = 1.0
        direction = jax.tree.map(
            lambda m, p: (m.astype(jnp.float32) / c1)
            / (jnp.sqrt(p.astype(jnp.float32) / c2) + eps), m, precond)

    if cfg.weight_decay:
        direction = jax.tree.map(
            lambda d, p: d + cfg.weight_decay * p.astype(jnp.float32),
            direction, params)

    new_params = jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) - lr * d).astype(p.dtype),
        params, direction)
    return new_params, new_state


def opt_state_bytes(cfg: AdaConfig, params: Pytree) -> int:
    """Optimizer-state memory footprint (for the dry-run memory report)."""
    n = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
    per = {"sgd": 0, "sgdm": 1, "adagrad": 1, "adam": 2, "amsgrad": 3}[cfg.name]
    return n * per * jnp.dtype(cfg.moment_dtype).itemsize
