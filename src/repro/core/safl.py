"""SAFL: Sketched Adaptive Federated Learning (paper Algorithm 1).

One SAFL round (faithful to Alg. 1):

  1. every client c syncs to the global iterate x_{t,0} and runs K local SGD
     steps with client lr eta:       x_{t,k} = x_{t,k-1} - eta * g_{t,k-1}
  2. client c uplinks the *sketched* local model delta
         m̄_t^c = sk(x_{t,0} - x_{t,K})          (b floats, not d)
  3. the server averages sketches   m̄_t = mean_c m̄_t^c   (linearity => this
     equals the sketch of the averaged delta; no server-side re-compression)
  4. server ADA_OPT (Alg. 2) consumes desk(m̄_t); the b-dim m̄_t is downlinked
     and every client replays the identical, deterministic server update, so
     all replicas stay synchronized.

Mesh mapping (DESIGN.md §3): a "client" is one data-parallel group of the
``(pod, data, model)`` mesh.  The client axis G is carried explicitly in the
batch (leading axis, sharded over (pod, data)); the sketch average over G is
a plain ``mean`` over one packed **(G, b_total)** payload that GSPMD lowers
to a single all-reduce of **b_total floats** -- the compressed uplink the
paper buys, in one collective instead of one per tensor.  Baselines that transmit raw deltas
(FedAvg / FedOpt) all-reduce O(d) instead; the roofline collective term shows
the gap directly.

The same round function serves the paper-scale simulation (G = 5 clients on
one device, exactly the paper's §5 setup) and the multi-pod production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.adaptive import AdaConfig, apply_update, init_opt_state
from repro.core.packed import (derive_round_params, desk_packed,
                               make_packing_plan, sk_packed_clients)
from repro.core.sketch import SketchConfig

Pytree = Any
LossFn = Callable[[Pytree, Any], jax.Array]  # (params, batch) -> scalar loss


@dataclasses.dataclass(frozen=True)
class SAFLConfig:
    sketch: SketchConfig = SketchConfig()
    server: AdaConfig = AdaConfig()
    client_lr: float = 0.1          # eta
    local_steps: int = 1            # K
    remat_local: bool = True        # jax.checkpoint around the local grad


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(lambda x, y: (x.astype(jnp.float32)
                                      - y.astype(jnp.float32)), a, b)


def mask_weights(mask) -> jax.Array:
    """The (G,) per-client weight vector of a participation mask.

    Plain (G,) arrays pass through; *weighted* masks -- dicts
    ``{"w": (G,) weights, "den": static denominator, "n": cohort size}``, as
    emitted by ``fed.participation.ImportanceParticipation`` -- contribute
    their weight vector.  A weight of 0 means "not sampled" in both forms.
    """
    return mask["w"] if isinstance(mask, dict) else mask


def masked_mean(x: jax.Array, mask) -> jax.Array:
    """Mean of ``x`` over its leading (client) axis, restricted to ``mask``.

    ``mask`` is a (G,) participation mask (1.0 = sampled).  ``mask=None``
    falls back to ``jnp.mean`` -- and an all-ones mask reproduces that path
    BITWISE: ``1.0 * x`` is exact, the axis-0 reduction lowers identically,
    and the denominator is the same float G (participation policies
    guarantee >=1 sampled client, so the max() guard never rewrites it).

    A weighted mask (dict form, see ``mask_weights``) computes
    ``sum(w * x) / den`` with the STATIC denominator the policy supplies --
    the Horvitz-Thompson form importance sampling needs (dividing by the
    random weight sum would turn the unbiased estimator into a ratio
    estimator).
    """
    if mask is None:
        return jnp.mean(x, axis=0)
    w = mask_weights(mask)
    m = w.reshape(w.shape + (1,) * (x.ndim - 1)).astype(x.dtype)
    if isinstance(mask, dict):
        return jnp.sum(x * m, axis=0) / jnp.asarray(mask["den"], x.dtype)
    den = jnp.maximum(jnp.sum(w), 1.0).astype(x.dtype)
    return jnp.sum(x * m, axis=0) / den


def masked_mean_tree(tree: Pytree, mask) -> Pytree:
    """``masked_mean`` over every leaf (leaves have leading client axis G)."""
    return jax.tree.map(lambda x: masked_mean(x, mask), tree)


def masked_psum_mean(x: jax.Array, w_loc: jax.Array, den,
                     client_axes) -> jax.Array:
    """``masked_mean`` distributed over shard_map client axes.

    ``x`` is a shard-local ``(G_loc, ...)`` block of the global client-major
    payload and ``w_loc`` the matching ``(G_loc,)`` slice of the cohort
    weights.  Computes the global cohort mean with the SAME collective count
    as the unmasked uplink: weighted local sum over the shard's client rows,
    ONE psum over the client axes (plus a scalar weight psum), divide.
    Returns a ``(1, ...)`` row (every shard holds the identical mean).

    ``den=None`` divides by the global weight sum (the 0/1-mask cohort
    mean); a static ``den`` is the Horvitz-Thompson denominator of a
    weighted mask (``core.safl.masked_mean`` semantics).  Bitwise pin: with
    an all-ones mask and one client row per shard this lowers to
    ``psum(x) / n`` -- exactly what ``lax.pmean`` computes -- so the masked
    route reproduces the unmasked trajectory bit for bit
    (tests/test_mesh_scan.py)."""
    w = w_loc.reshape((w_loc.shape[0],) + (1,) * (x.ndim - 1)).astype(x.dtype)
    sw = jnp.sum(x * w, axis=0, keepdims=True)
    if den is None:
        wsum = jnp.sum(w_loc)
        if client_axes:
            sw = jax.lax.psum(sw, client_axes)
            wsum = jax.lax.psum(wsum, client_axes)
        return sw / jnp.maximum(wsum, 1.0).astype(x.dtype)
    if client_axes:
        sw = jax.lax.psum(sw, client_axes)
    return sw / jnp.asarray(den, x.dtype)


def masked_where_tree(mask, new: Pytree, old: Pytree) -> Pytree:
    """Per-client state select: sampled clients take ``new`` leaves, the rest
    keep ``old`` (leaves (G, ...)).  Used for error-feedback memories under
    partial participation; ``mask=None`` (and, bitwise, an all-ones mask)
    returns ``new`` unchanged.  Weighted masks select on ``w > 0``."""
    if mask is None:
        return new
    w = mask_weights(mask)
    def sel(n, o):
        m = w.reshape(w.shape + (1,) * (n.ndim - 1))
        return jnp.where(m > 0, n, o)
    return jax.tree.map(sel, new, old)


def client_delta(cfg: SAFLConfig, loss_fn: LossFn, params: Pytree,
                 microbatches: Pytree, eta: jax.Array) -> tuple[Pytree, jax.Array]:
    """K local SGD steps for ONE client; returns (x_0 - x_K, mean local loss).

    ``microbatches`` leaves have leading axis K (one slice per local step).
    """
    grad_fn = jax.value_and_grad(loss_fn)
    if cfg.remat_local:
        grad_fn = jax.checkpoint(grad_fn)

    def step(p, mb):
        loss, g = grad_fn(p, mb)
        p = jax.tree.map(
            lambda x, gi: (x.astype(jnp.float32)
                           - eta * gi.astype(jnp.float32)).astype(x.dtype),
            p, g)
        return p, loss

    p_final, losses = jax.lax.scan(step, params, microbatches)
    return tree_sub(params, p_final), jnp.mean(losses)


# ---------------------------------------------------------------------------
# streamed client-microbatch aggregation (DESIGN.md §12)
# ---------------------------------------------------------------------------

def resolve_microbatch(microbatch, num_clients: int):
    """Static routing of the streamed-aggregation knob (DESIGN.md §12).

    ``None`` -- or any chunk size covering the whole cohort -- selects the
    materialized single-chunk path, UNTOUCHED from the pinned program: a
    fold with one chunk is semantically the existing round, so the knob
    routes at Python level and the pinned bitwise trajectories survive by
    construction.  A chunk size below ``num_clients`` returns the validated
    int and selects the streamed fold, which is its own program family
    (pinned within itself, allclose to the materialized path).
    """
    if microbatch is None:
        return None
    mb = int(microbatch)
    if mb <= 0:
        raise ValueError(f"microbatch must be a positive int, got {microbatch}")
    if mb >= num_clients:
        return None
    return mb


def chunk_clients(tree: Pytree, mb: int, pad: int) -> Pytree:
    """Zero-pad the leading client axis by ``pad`` rows and reshape every
    leaf to ``(n_mb, mb, ...)`` microbatch chunks (scan xs layout).  The pad
    rows are masked out by the fold (weight 0 AND statically zeroed payload
    -- see ``streamed_sketch_round``), so any ``mb`` is valid: a non-dividing
    ``G % mb`` costs one masked tail chunk, never a reordered reduction."""
    def f(x):
        if pad:
            x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
        return x.reshape((-1, mb) + x.shape[1:])
    return jax.tree.map(f, tree)


def _pad_fault_spec(spec: dict, pad: int) -> dict:
    """Extend a (G,) fault spec with ``pad`` NEUTRAL rows (arrive, honest
    scale, no corruption): pad clients carry weight 0, and a neutral spec
    keeps their zeroed payload finite so 0-weight rows contribute exactly
    +0.0 to the fold."""
    if not pad:
        return spec
    neutral = {"arrive": 1.0, "nan": False, "inf": False, "scale": 1.0}
    return {k: jnp.pad(v, (0, pad), constant_values=neutral[k])
            for k, v in spec.items()}


def streamed_sketch_round(cfg: SAFLConfig, client_fn, params: Pytree,
                          opt_state: dict, batch: Pytree,
                          round_key: jax.Array, mb: int, *,
                          lr_scale: jax.Array | float = 1.0, plan=None,
                          part_mask=None, fault_spec=None, sentinel=None,
                          telemetry=None,
                          codec=None) -> tuple[Pytree, dict, dict]:
    """One sketched round as a fold over client microbatches (DESIGN.md §12).

    Instead of materializing the ``(G, d_total)`` delta stack and the
    ``(G, b_total)`` payload, a ``lax.scan`` processes ``mb`` clients per
    step -- ``client_fn(batch_slice) -> (delta_tree, loss)`` for ONE client,
    vmapped over the chunk -- and carries only the running weighted
    sketch-sum, weight-sum and loss-sum: peak payload memory is
    ``O(mb * b_total)``, independent of G.  Exactness rests on sketch
    linearity (Property 1): the sum of per-chunk sketch sums IS the sketch
    of the weighted delta sum, so the single desketch at the end sees the
    same cohort mean the materialized path computes (equal up to f32
    summation order -- the streamed family is pinned within itself, see
    ``resolve_microbatch``).

    The repro.fed hook contract is preserved per-microbatch against the
    GLOBAL client index: the (G,) participation weights and the (G,) fault
    spec are sliced to rows ``[i*mb, (i+1)*mb)`` of chunk i, which is exact
    because every per-client stream is a pure function of the absolute
    client index (DESIGN.md §7/§10).  The §10 fusion order (faults ->
    sentinels -> mask -> one reduction) runs inside each chunk, except the
    norm-outlier sentinel: its median is a GLOBAL cohort statistic, so
    ``norm_mult > 0`` runs a two-pass fold (pass 1 streams per-client norm/
    finite/loss stats, the median + verdicts are computed between passes,
    pass 2 deterministically recomputes deltas and accumulates the payload
    sum under the final weights) -- 2x client compute is the price of a
    global statistic under O(mb) memory.

    Non-dividing ``G % mb`` pads a masked tail chunk: pad rows carry weight
    0 AND a statically zeroed payload/loss (pad positions are known at
    trace time), so not even a NaN produced by the synthetic zero batch can
    leak into the sums.

    ``codec`` (static ``fed.codec.CodecConfig``, threaded like ``plan``)
    quantize-dequantizes each chunk's payload rows BEFORE the fault/
    sentinel stages (DESIGN.md §13): the rounding uniforms key off the
    GLOBAL client index, so the fold draws exactly the uniforms the
    materialized path would, and the error-feedback memory rides the xs as
    global-offset row slices with the per-chunk residual emitted as scan
    ys (the fold's linearity argument is unchanged -- it sums DECODED
    rows).  With ``codec.error_feedback``, ``opt_state`` is the wrapped
    ``{"opt": ..., "ef": (G, b_total)}`` dict.
    """
    if telemetry is not None:
        raise ValueError(
            "telemetry probes consume the materialized (G, ...) delta "
            "stack; the streamed microbatch fold never builds it -- run "
            "telemetry with microbatch=None")
    if plan is None:
        plan = make_packing_plan(cfg.sketch, params)
    rp = derive_round_params(plan, round_key)

    ef_wrapped = codec is not None and codec.error_feedback
    opt_orig = opt_state
    ef = None
    if ef_wrapped:
        ef, opt_state = opt_orig["ef"], opt_orig["opt"]
    if codec is not None:
        from repro.fed.codec import encode_decode

    G = jax.tree.leaves(batch)[0].shape[0]
    n_mb = -(-G // mb)
    pad = n_mb * mb - G

    w0 = (jnp.ones((G,), jnp.float32) if part_mask is None
          else mask_weights(part_mask).astype(jnp.float32))
    xs = {"batch": chunk_clients(batch, mb, pad),
          "w": jnp.pad(w0, (0, pad)).reshape(n_mb, mb)}
    if pad:
        xs["real"] = jnp.pad(jnp.ones((G,), bool),
                             (0, pad)).reshape(n_mb, mb)
    if fault_spec is not None:
        spec_p = _pad_fault_spec(fault_spec, pad)
        xs["spec"] = {k: v.reshape((n_mb, mb)) for k, v in spec_p.items()}
    if codec is not None:
        # global client ids key the rounding uniforms; pad ids are harmless
        # (their rows are statically zeroed and weight-0)
        xs["cid"] = jnp.pad(jnp.arange(G, dtype=jnp.int32),
                            (0, pad)).reshape(n_mb, mb)
        if ef_wrapped:
            xs["ef"] = jnp.pad(ef, ((0, pad), (0, 0))).reshape(n_mb, mb, -1)

    def chunk_payload(xc):
        """One chunk's (mb, b_total) sketches, (mb,) losses, post-arrival
        weights and EF residual, §10/§13 order (decode before corruption
        before any vetting)."""
        deltas, losses = jax.vmap(client_fn)(xc["batch"])
        sks = sk_packed_clients(plan, rp, deltas).astype(jnp.float32)
        if pad:     # static: hard-zero the tail-pad rows
            sks = jnp.where(xc["real"][:, None], sks, jnp.float32(0.0))
            losses = jnp.where(xc["real"], losses, jnp.float32(0.0))
        ef_c = None
        if codec is not None:
            sks, ef_c = encode_decode(
                codec, round_key, sks,
                ef_rows=xc["ef"] if ef_wrapped else None,
                client_ids=xc["cid"])
        w = xc["w"]
        if fault_spec is not None:
            from repro.fed.faults import corrupt_payload
            sks = corrupt_payload(xc["spec"], sks)
            w = w * xc["spec"]["arrive"]
        return sks, losses, w, ef_c

    counters = {}
    if fault_spec is not None:
        from repro.fed.faults import n_dropped
        counters["n_dropped"] = n_dropped(fault_spec, part_mask)

    S0 = jnp.zeros((plan.b_total,), jnp.float32)
    n_tx = None                  # billed transmitters (codec accounting)
    if sentinel is None or sentinel.norm_mult == 0.0:
        # single pass: the finite-check verdict is row-local, so faults ->
        # sentinel -> mask fuse inside each chunk
        init = (S0, jnp.float32(0.0), jnp.float32(0.0),
                jnp.zeros((), jnp.int32))
        if codec is not None:    # extra carry leaf: codec's program family
            init += (jnp.float32(0.0),)

        def body(carry, xc):
            S, W, L, n_rej = carry[:4]
            sks, losses, w, ef_c = chunk_payload(xc)
            if sentinel is not None:
                ok = jnp.isfinite(sks).all(axis=-1)
                sks = jnp.where(ok[:, None], sks, jnp.float32(0.0))
                n_rej = n_rej + jnp.sum((w > 0) & ~ok)
                w = w * ok.astype(jnp.float32)
            out = (S + jnp.sum(sks * w[:, None], axis=0), W + jnp.sum(w),
                   L + jnp.sum(w * losses), n_rej)
            if codec is not None:
                out += (carry[4] + jnp.sum((w > 0).astype(jnp.float32)),)
            return out, ef_c

        res, ef_ys = jax.lax.scan(body, init, xs)
        S, W, L, n_rej = res[:4]
        if codec is not None:
            n_tx = res[4]
        if sentinel is not None:
            counters["n_rejected"] = n_rej
    else:
        # two-pass: the norm-outlier median needs the whole cohort's stats
        def stats(carry, xc):
            sks, losses, w, ef_c = chunk_payload(xc)
            ok = jnp.isfinite(sks).all(axis=-1)
            clean = jnp.where(ok[:, None], sks, jnp.float32(0.0))
            return carry, (losses, jnp.sum(jnp.square(clean), axis=-1),
                           ok, w, ef_c)

        _, (losses_c, nrm2_c, ok_c, w_c, ef_ys) = jax.lax.scan(stats, 0, xs)
        losses_p, nrm2_p = losses_c.reshape(-1), nrm2_c.reshape(-1)
        ok_p, w_arr = ok_c.reshape(-1), w_c.reshape(-1)
        from repro.fed.robust import masked_median
        pool = (w_arr > 0) & ok_p
        med2 = masked_median(nrm2_p, pool)
        valid = ok_p & (nrm2_p <= sentinel.norm_mult ** 2 * med2)
        counters["n_rejected"] = jnp.sum((w_arr > 0) & ~valid)
        w_eff = w_arr * valid.astype(jnp.float32)
        if codec is not None:
            n_tx = jnp.sum((w_eff > 0).astype(jnp.float32))

        xs2 = {**xs, "ok": ok_c, "we": w_eff.reshape(n_mb, mb)}

        def accum(S, xc):
            # deltas/sketches/codec draws are pure in (params, batch, rp,
            # round_key): recomputing them is deterministic, so pass 2
            # streams the SAME (decoded) payloads
            sks, _, _, _ = chunk_payload(xc)
            clean = jnp.where(xc["ok"][:, None], sks, jnp.float32(0.0))
            return S + jnp.sum(clean * xc["we"][:, None], axis=0), None

        S, _ = jax.lax.scan(accum, S0, xs2)
        W = jnp.sum(w_eff)
        L = jnp.sum(w_eff * losses_p)

    den = (jnp.asarray(part_mask["den"], jnp.float32)
           if isinstance(part_mask, dict) else jnp.maximum(W, 1.0))
    mbar = S / den
    loss = L / den

    update = desk_packed(plan, rp, mbar)
    new_params, new_opt = apply_update(cfg.server, opt_state, params, update,
                                       lr_scale=lr_scale)
    if ef_wrapped:
        # unsampled clients (pre-fault weight 0) freeze their EF memory;
        # the tail-pad ys rows are sliced off before anything reads them
        ef_new = ef_ys.reshape(n_mb * mb, -1)[:G]
        new_opt = {"opt": new_opt,
                   "ef": jnp.where((w0 > 0)[:, None], ef_new, ef)}
    if codec is not None:
        counters["uplink_bits"] = (
            jnp.float32(codec.payload_bits(plan.b_total)) * n_tx)
    if sentinel is not None:
        from repro.fed.robust import carry_if_empty, divergence_flag
        # the scalar surviving weight W plays the eff-mask role: its sum is
        # itself, which is all carry_if_empty consumes.  The wrapped EF
        # memory reverts with the server state on an empty cohort
        # (conservative; DESIGN.md §13)
        new_params, new_opt = carry_if_empty(W, (new_params, new_opt),
                                             (params, opt_orig))
        counters = {**counters, "diverged": divergence_flag(sentinel, loss)}
    return new_params, new_opt, {"loss": loss, **counters}


def safl_round(cfg: SAFLConfig, loss_fn: LossFn, params: Pytree,
               opt_state: dict, batch: Pytree, round_key: jax.Array,
               eta_scale: jax.Array | float = 1.0,
               lr_scale: jax.Array | float = 1.0, *,
               plan=None, part_mask=None, fault_spec=None,
               sentinel=None, telemetry=None,
               microbatch=None, codec=None) -> tuple[Pytree, dict, dict]:
    """One full SAFL round over all clients.

    ``batch`` leaves are shaped (G, K, mb, ...): G clients (sharded over the
    (pod, data) mesh axes in distributed mode), K local steps each.
    ``plan`` is the static packing layout; multi-round callers (the scan
    driver) build it ONCE outside the trace and thread it through via
    ``functools.partial`` -- only the round operator (``derive_round_params``)
    depends on ``round_key``.  ``part_mask`` (optional, (G,)) restricts the
    server aggregation to the round's sampled cohort (repro.fed): the sketch
    mean divides by the SAMPLED cohort size; an all-ones mask is bitwise the
    full-participation path.  ``fault_spec`` (traced, from
    ``fed.faults.*.spec``) injects payload faults and ``sentinel`` (static
    ``fed.robust.SentinelConfig``, threaded like ``plan`` via partial)
    rejects bad payloads before aggregation -- the faults -> sentinels ->
    mask fusion of DESIGN.md §10.  ``telemetry`` (static
    ``repro.obs.Telemetry``, threaded like ``plan`` via partial) adds the
    selected probe scalars to the metrics; it is None by default because any
    extra scan output shifts XLA fusion and hence the pinned f32
    trajectories (DESIGN.md §11).  ``microbatch`` (static) streams the
    aggregation over chunks of that many clients instead of materializing
    the full cohort (DESIGN.md §12) -- ``None`` or any value >= G keeps the
    materialized path below untouched, so the pinned trajectories survive.
    ``codec`` (static ``fed.codec.CodecConfig``, threaded like ``plan``)
    quantize-dequantizes the payload rows between the fused sketch and the
    guard/mean stages, with sketch-space error feedback, and replaces the
    float32 ``uplink_bits`` fiction with the MEASURED encoded size
    (DESIGN.md §13); ``codec=None`` routes at Python level, keeping the
    pinned trajectories byte-identical.  With ``codec.error_feedback``,
    ``opt_state`` is the wrapped ``{"opt": ..., "ef": (G, b_total)}`` dict
    (``fed.codec.init_codec_state``).
    Returns (params, opt_state, metrics).
    """
    if codec is not None and telemetry is not None:
        raise ValueError(
            "telemetry probes read the bare server opt state; under "
            "codec.error_feedback the round state is the wrapped "
            "{'opt', 'ef'} dict -- run telemetry without a codec")
    eta = jnp.asarray(cfg.client_lr * eta_scale, jnp.float32)

    if microbatch is not None:
        mb = resolve_microbatch(microbatch,
                                jax.tree.leaves(batch)[0].shape[0])
        if mb is not None:
            return streamed_sketch_round(
                cfg, lambda b: client_delta(cfg, loss_fn, params, b, eta),
                params, opt_state, batch, round_key, mb, lr_scale=lr_scale,
                plan=plan, part_mask=part_mask, fault_spec=fault_spec,
                sentinel=sentinel, telemetry=telemetry, codec=codec)

    ef_wrapped = codec is not None and codec.error_feedback
    opt_orig = opt_state
    ef = None
    if ef_wrapped:
        ef, opt_state = opt_orig["ef"], opt_orig["opt"]

    # --- client updates (vmapped over the client axis; params broadcast) ---
    deltas, losses = jax.vmap(
        lambda mb: client_delta(cfg, loss_fn, params, mb, eta))(batch)

    # --- uplink: sketch each client's delta with the SHARED round operator
    # (Remark 3.1: same seed across clients within a round).  The packed
    # engine derives the operator ONCE for sk and desk and compresses the
    # whole tree in one fused pass -> (G, b_total) payload. ---
    if plan is None:
        plan = make_packing_plan(cfg.sketch, params)
    rp = derive_round_params(plan, round_key)
    sketches = sk_packed_clients(plan, rp, deltas)

    # --- payload codec (DESIGN.md §13): quantize-dequantize each client's
    # row (plus its EF residual) BEFORE faults/sentinels -- corruption
    # happens in transit to the ENCODED bytes, and the server can only vet
    # what it decodes.  Unsampled clients freeze their EF memory. ---
    if codec is not None:
        from repro.fed.codec import encode_decode
        sketches = sketches.astype(jnp.float32)
        if ef_wrapped:
            sketches, ef_new = encode_decode(codec, round_key, sketches,
                                             ef_rows=ef)
            ef = masked_where_tree(part_mask, ef_new, ef)
        else:
            sketches, _ = encode_decode(codec, round_key, sketches)

    # --- fault injection + sentinel rejection, both in sketch space; the
    # survivors' weights land in the SAME mask the cohort mean already
    # consumes (lazy import: repro.fed imports this module) ---
    counters = {}
    if fault_spec is not None or sentinel is not None:
        from repro.fed.robust import guard_uplink
        sketches, part_mask, counters = guard_uplink(
            sketches, part_mask, fault_spec, sentinel)

    # --- server: average of sketches == sketch of average (Property 1).
    # Under GSPMD this mean over the client axis is the ONLY cross-client
    # collective, and it moves b_total floats, not d.  Under partial
    # participation only the sampled cohort contributes, and the mean
    # divides by the cohort size, not N. ---
    mbar = masked_mean(sketches, part_mask)

    # --- desk back to R^d and run ADA_OPT (Alg. 2); deterministic, so every
    # replica/client replays the identical server step. ---
    update = desk_packed(plan, rp, mbar)
    new_params, new_opt = apply_update(cfg.server, opt_state, params, update,
                                       lr_scale=lr_scale)
    if ef_wrapped:
        new_opt = {"opt": new_opt, "ef": ef}
    if codec is not None:
        # MEASURED wire size: encoded row bits x the effective post-guard
        # transmitting cohort (guard_uplink rebound part_mask above)
        from repro.fed.codec import measured_uplink_bits
        counters["uplink_bits"] = measured_uplink_bits(
            codec, plan.b_total, eff_mask=part_mask,
            num_clients=losses.shape[0])

    loss = masked_mean(losses, part_mask)
    if sentinel is not None:
        from repro.fed.robust import carry_if_empty, divergence_flag
        # the wrapped EF memory reverts with the server state on an empty
        # cohort (conservative; DESIGN.md §13)
        new_params, new_opt = carry_if_empty(
            part_mask, (new_params, new_opt), (params, opt_orig))
        counters = {**counters, "diverged": divergence_flag(sentinel, loss)}

    metrics = {"loss": loss, **counters}
    if telemetry is not None:
        # part_mask here is the EFFECTIVE mask (guard_uplink rebinds it), so
        # the probes and the aggregation see the same cohort
        from repro.obs.telemetry import telemetry_probes
        metrics.update(telemetry_probes(
            telemetry, deltas=deltas, update=update, part_mask=part_mask,
            state=new_opt))
    return new_params, new_opt, metrics


def fedopt_round(cfg: SAFLConfig, loss_fn: LossFn, params: Pytree,
                 opt_state: dict, batch: Pytree, round_key: jax.Array,
                 eta_scale: jax.Array | float = 1.0,
                 lr_scale: jax.Array | float = 1.0, *,
                 part_mask=None, fault_spec=None,
                 sentinel=None, telemetry=None,
                 microbatch=None, codec=None) -> tuple[Pytree, dict, dict]:
    """Uncompressed FedOPT (Reddi et al. 2020) round: the paper's
    'ambient-dimension' reference line (legend 4e7 / 1e8).  Identical to
    safl_round with the identity compressor -- clients uplink raw deltas,
    i.e. the mean below all-reduces O(d) floats."""
    if fault_spec is not None or sentinel is not None:
        raise ValueError(
            "fault injection / payload sentinels act on the packed sketch "
            "uplink (fed.faults / fed.robust); the uncompressed FedOPT "
            "baseline has no sketch payload -- run them on the SAFL/SACFL "
            "rounds")
    if codec is not None:
        raise ValueError(
            "the payload codec quantizes the packed sketch uplink "
            "(fed.codec, DESIGN.md §13); the uncompressed FedOPT baseline "
            "has no sketch payload -- run the codec on the SAFL/SACFL "
            "rounds")
    eta = jnp.asarray(cfg.client_lr * eta_scale, jnp.float32)

    if microbatch is not None:
        mb = resolve_microbatch(microbatch,
                                jax.tree.leaves(batch)[0].shape[0])
        if mb is not None:
            return _streamed_fedopt_round(
                cfg, loss_fn, params, opt_state, batch, eta, mb,
                lr_scale=lr_scale, part_mask=part_mask, telemetry=telemetry)

    deltas, losses = jax.vmap(
        lambda mb: client_delta(cfg, loss_fn, params, mb, eta))(batch)
    update = masked_mean_tree(deltas, part_mask)
    params, opt_state = apply_update(cfg.server, opt_state, params, update,
                                     lr_scale=lr_scale)
    metrics = {"loss": masked_mean(losses, part_mask)}
    if telemetry is not None:
        # the uncompressed update IS the cohort-mean delta, so the desketch
        # residual probe reads exactly 0 -- the reference line
        from repro.obs.telemetry import telemetry_probes
        metrics.update(telemetry_probes(
            telemetry, deltas=deltas, update=update, part_mask=part_mask,
            state=opt_state))
    return params, opt_state, metrics


def _streamed_fedopt_round(cfg: SAFLConfig, loss_fn: LossFn, params: Pytree,
                           opt_state: dict, batch: Pytree, eta: jax.Array,
                           mb: int, *, lr_scale=1.0, part_mask=None,
                           telemetry=None) -> tuple[Pytree, dict, dict]:
    """Streamed fold of the uncompressed FedOPT round: the raw-delta mean is
    a plain weighted tree sum, so the microbatch carry is one O(d) tree plus
    the weight/loss scalars instead of the (G, d) delta stack.  Same masked
    tail contract as ``streamed_sketch_round``."""
    if telemetry is not None:
        raise ValueError(
            "telemetry probes consume the materialized (G, ...) delta "
            "stack; the streamed microbatch fold never builds it -- run "
            "telemetry with microbatch=None")
    G = jax.tree.leaves(batch)[0].shape[0]
    n_mb = -(-G // mb)
    pad = n_mb * mb - G
    w0 = (jnp.ones((G,), jnp.float32) if part_mask is None
          else mask_weights(part_mask).astype(jnp.float32))
    xs = {"batch": chunk_clients(batch, mb, pad),
          "w": jnp.pad(w0, (0, pad)).reshape(n_mb, mb)}
    if pad:
        xs["real"] = jnp.pad(jnp.ones((G,), bool),
                             (0, pad)).reshape(n_mb, mb)

    S0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)

    def body(carry, xc):
        S, W, L = carry
        deltas, losses = jax.vmap(
            lambda b: client_delta(cfg, loss_fn, params, b, eta))(xc["batch"])
        w = xc["w"]
        if pad:     # static: hard-zero the tail-pad rows
            deltas = jax.tree.map(
                lambda d: jnp.where(
                    xc["real"].reshape((mb,) + (1,) * (d.ndim - 1)), d,
                    jnp.float32(0.0)), deltas)
            losses = jnp.where(xc["real"], losses, jnp.float32(0.0))
        S = jax.tree.map(
            lambda s, d: s + jnp.sum(
                d * w.reshape((mb,) + (1,) * (d.ndim - 1)), axis=0),
            S, deltas)
        return (S, W + jnp.sum(w), L + jnp.sum(w * losses)), None

    (S, W, L), _ = jax.lax.scan(
        body, (S0, jnp.float32(0.0), jnp.float32(0.0)), xs)
    den = (jnp.asarray(part_mask["den"], jnp.float32)
           if isinstance(part_mask, dict) else jnp.maximum(W, 1.0))
    update = jax.tree.map(lambda s: s / den, S)
    params, opt_state = apply_update(cfg.server, opt_state, params, update,
                                     lr_scale=lr_scale)
    return params, opt_state, {"loss": L / den}


def init_safl(cfg: SAFLConfig, params: Pytree) -> dict:
    """Server moment state (m_0 = v_0 = v̂_0 = 0)."""
    return init_opt_state(cfg.server, params)


def split_client_batches(batch: Pytree, num_clients: int, local_steps: int) -> Pytree:
    """Reshape a global batch (B, ...) -> (G, K, B/(G*K), ...)."""
    def reshape(x):
        b = x.shape[0]
        assert b % (num_clients * local_steps) == 0, (
            f"batch {b} not divisible by G*K={num_clients * local_steps}")
        return x.reshape(num_clients, local_steps,
                         b // (num_clients * local_steps), *x.shape[1:])
    return jax.tree.map(reshape, batch)


def uplink_bits_per_round(cfg: SAFLConfig, params: Pytree,
                          cohort_size: int = 1) -> int:
    """Uplink payload in bits per round (paper's communication metric).

    ``cohort_size`` is the number of clients that actually transmit in a
    round: under partial participation (repro.fed) this is the SAMPLED
    cohort size, not N -- pass ``policy.cohort_size`` to get the honest
    per-round total.  The default (1) reports the per-client payload, the
    seed semantics."""
    from repro.core.sketch import total_sketch_bits
    assert cohort_size >= 1, "a round must have at least one uplinking client"
    return total_sketch_bits(cfg.sketch, params) * int(cohort_size)
