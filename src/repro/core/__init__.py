from repro.core.adaptive import AdaConfig, apply_update, init_opt_state
from repro.core.safl import (SAFLConfig, client_delta, fedopt_round, init_safl,
                             safl_round, split_client_batches,
                             uplink_bits_per_round)
from repro.core.packed import (PackingPlan, derive_round_params, desk_packed,
                               make_packing_plan, roundtrip_packed, sk_packed,
                               sk_packed_clients)
from repro.core.sketch import (SketchConfig, desketch_tree, leaf_sketch_size,
                               roundtrip_tree, sketch_tree, total_sketch_bits)
