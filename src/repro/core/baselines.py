"""Communication-efficient FL baselines the paper compares against (§5, Table 1).

All baselines share the SAFL round interface:
    round(cfg, loss_fn, params, state, batch(G, K, mb, ...), key)
        -> (params, state, metrics)

Implemented:
  * ``fedavg``      -- plain local-SGD averaging (uncompressed, server SGD)
  * ``fedopt``      -- uncompressed adaptive server (Reddi et al. 2020); the
                       paper's "ambient dimension" reference (in safl.py)
  * ``topk_ef``     -- Top-K sparsification + client error feedback
                       (Stich et al. 2018)
  * ``fetchsgd``    -- Count-Sketch uplink, server sketch-momentum + sketch
                       error accumulation + heavy-hitter Top-K unsketch
                       (Rothchild et al. 2020)
  * ``onebit_adam`` -- Adam warmup, then frozen-variance sign compression
                       with error feedback (Tang et al. 2021)
  * ``marina``      -- unbiased compressed gradient differences with periodic
                       full sync (Gorbunov et al. 2021a), Rand-K compressor
  * ``cocktail``    -- simplified CocktailSGD (Wang et al. 2023): Rand-K then
                       sign quantization, wrapped in error feedback.  (The
                       full pipeline also stages top-k; we document this
                       simplification in EXPERIMENTS.md.)

These run in the paper-scale simulation path (C clients on one device) for
the convergence benchmarks; ``fedopt`` and ``safl`` additionally run on the
production mesh where their O(d) vs O(b) collectives are rooflined.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.adaptive import AdaConfig, apply_update, init_opt_state
from repro.core.packed import (derive_round_params, desk_flat,
                               make_packing_plan, pack_tree, sk_flat,
                               sk_packed_clients, unpack_tree)
from repro.core.safl import SAFLConfig, client_delta
from repro.core.sketch import SketchConfig

Pytree = Any
LossFn = Callable[[Pytree, Any], jax.Array]


@dataclasses.dataclass(frozen=True)
class BaselineConfig:
    name: str = "fedavg"
    client_lr: float = 0.1
    local_steps: int = 1
    server: AdaConfig = AdaConfig(name="sgd", lr=1.0)
    # compression knobs
    topk_ratio: float = 0.01        # fraction of coords kept (topk/randk)
    sketch: SketchConfig = SketchConfig(kind="countsketch", ratio=0.01)
    fetchsgd_momentum: float = 0.9
    fetchsgd_shrink: float = 0.0    # heavy-hitter shrinkage; 0 = auto (b/n)
    onebit_warmup: int = 10
    marina_p: float = 0.1           # prob of full-gradient sync round
    seed_tag: int = 0


# --------------------------------------------------------------------------
# compressors (per flat vector)
# --------------------------------------------------------------------------

def topk_mask(v: jax.Array, k: int) -> jax.Array:
    """Dense mask keeping the k largest-|.| entries (biased, contractive)."""
    k = max(1, min(k, v.shape[0]))
    thresh = jax.lax.top_k(jnp.abs(v), k)[0][-1]
    return jnp.where(jnp.abs(v) >= thresh, v, 0.0)


def randk_unbiased(key: jax.Array, v: jax.Array, k: int) -> jax.Array:
    """Unbiased Rand-K: keep k random coords scaled by n/k (omega = n/k - 1)."""
    n = v.shape[0]
    k = max(1, min(k, n))
    idx = jax.random.choice(key, n, (k,), replace=False)
    mask = jnp.zeros((n,), v.dtype).at[idx].set(1.0)
    return v * mask * (n / k)


def sign_quant(v: jax.Array) -> jax.Array:
    """1-bit sign quantization with l1 scale (1bit-Adam / signSGD style)."""
    return jnp.sign(v) * jnp.mean(jnp.abs(v))


def _per_leaf(fn, tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return jax.tree_util.tree_unflatten(
        treedef, [fn(i, l.reshape(-1)).reshape(l.shape)
                  for i, l in enumerate(leaves)])


# --------------------------------------------------------------------------
# state
# --------------------------------------------------------------------------

def init_baseline_state(cfg: BaselineConfig, params: Pytree, num_clients: int) -> dict:
    f32 = lambda t: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)
    state = {"opt": init_opt_state(cfg.server, params),
             "round": jnp.zeros((), jnp.int32)}
    if cfg.name in ("topk_ef", "onebit_adam", "cocktail", "cdadam"):
        # per-client error memories
        state["err"] = jax.tree.map(
            lambda p: jnp.zeros((num_clients,) + p.shape, jnp.float32), params)
    if cfg.name == "fetchsgd":
        # sketch-space accumulators live in the packed (b_total,) payload
        plan = make_packing_plan(cfg.sketch, params)
        state["sk_mom"] = jnp.zeros((plan.b_total,), jnp.float32)
        state["sk_err"] = jnp.zeros((plan.b_total,), jnp.float32)
    if cfg.name == "marina":
        state["g"] = f32(params)
        state["prev_params"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    if cfg.name == "onebit_adam":
        state["v_frozen"] = f32(params)
    return state


def _deltas_and_losses(cfg: BaselineConfig, loss_fn, params, batch, eta):
    scfg = SAFLConfig(client_lr=cfg.client_lr, local_steps=cfg.local_steps)
    return jax.vmap(lambda mb: client_delta(scfg, loss_fn, params, mb, eta))(batch)


# --------------------------------------------------------------------------
# rounds
# --------------------------------------------------------------------------

def baseline_round(cfg: BaselineConfig, loss_fn: LossFn, params: Pytree,
                   state: dict, batch: Pytree, key: jax.Array
                   ) -> tuple[Pytree, dict, dict]:
    eta = jnp.asarray(cfg.client_lr, jnp.float32)
    rnd = state["round"]
    deltas, losses = _deltas_and_losses(cfg, loss_fn, params, batch, eta)
    metrics = {"loss": jnp.mean(losses)}
    G = jax.tree.leaves(deltas)[0].shape[0]

    if cfg.name == "fedavg" or cfg.name == "fedopt":
        update = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas)
        params, state["opt"] = apply_update(cfg.server, state["opt"], params, update)

    elif cfg.name in ("topk_ef", "cocktail", "cdadam"):
        # packed layout (DESIGN.md §4): error memory + delta flattened into
        # one (G, d_total) buffer; the compressor runs ONCE on the packed
        # vector (global top-k / rand-k, the canonical formulation) instead
        # of a per-leaf loop.
        plan = make_packing_plan(cfg.sketch, params)
        a2 = jax.vmap(lambda t: pack_tree(plan, t))(
            jax.tree.map(lambda e, d: e + d, state["err"], deltas))
        k = max(1, int(plan.d_total * cfg.topk_ratio))
        if cfg.name == "cocktail":
            def comp_one(g, v):
                kk = jax.random.fold_in(key, g)
                # biased Rand-K (no n/k inflation -- EF absorbs the bias)
                n = v.shape[0]
                idx = jax.random.choice(kk, n, (k,), replace=False)
                mask = jnp.zeros((n,), v.dtype).at[idx].set(1.0)
                sparse = v * mask
                # sign-quantize the survivors (scale = mean |.| over k)
                scale = jnp.sum(jnp.abs(sparse)) / k
                return jnp.sign(sparse) * scale
            comp = jax.vmap(comp_one)(jnp.arange(G), a2)
        else:
            comp = jax.vmap(lambda v: topk_mask(v, k))(a2)
        state["err"] = jax.vmap(
            lambda f: unpack_tree(plan, f, cast=False))(a2 - comp)
        update = unpack_tree(plan, jnp.mean(comp, axis=0), cast=False)
        params, state["opt"] = apply_update(cfg.server, state["opt"], params, update)

    elif cfg.name == "fetchsgd":
        # NOTE: canonical FetchSGD keeps ONE fixed sketch so momentum/error
        # accumulate coherently -- but that variant provably relies on the
        # heavy-hitter assumption (paper Table 1 note (A)); on dense
        # (non-heavy-hitter) gradients the fixed-hash aliasing is a positive
        # feedback loop and it diverges (we verified: see EXPERIMENTS.md
        # §Baselines).  We therefore re-key the sketch each round: the
        # sketch-space accumulators then act as unbiased compressed momentum
        # + error smoothing, which is stable without heavy hitters.
        #
        # The packed engine (DESIGN.md §4) sketches all clients x all leaves
        # in one fused pass; per-leaf key derivation (fold_in on the leaf
        # index) is identical to the old per-leaf loop, so with
        # cs_hash="independent" trajectories match the pre-packed code
        # exactly (the default "balanced" family is a different -- equally
        # valid -- count-sketch operator).  Momentum/error accumulate in
        # the (b_total,) payload.
        plan = make_packing_plan(cfg.sketch, params)
        rp = derive_round_params(plan, key)
        # clients sketch; server averages sketches (mergeable)
        sks = sk_packed_clients(plan, rp, deltas)           # (G, b_total)
        s_mean = jnp.mean(sks.astype(jnp.float32), axis=0)
        mom = cfg.fetchsgd_momentum * state["sk_mom"] + s_mean
        er = state["sk_err"] + mom
        dense = desk_flat(plan, rp, er)                     # unsketch error acc
        # top-k selection on a desketch picks upward-biased coordinates;
        # shrink by ~b/n so the applied mass matches the true signal
        # (without this the EF loop is a positive feedback on dense,
        # non-heavy-hitter gradients -- see EXPERIMENTS.md §Baselines)
        upd_parts = []
        for op in plan.ops:
            dvec = dense[op.in_off:op.in_off + op.n]
            k = max(1, int(op.n * cfg.topk_ratio))
            shrink = cfg.fetchsgd_shrink or min(1.0, op.b / op.n)
            upd_parts.append(topk_mask(dvec, k) * shrink)   # heavy hitters
        upd_flat = jnp.concatenate(upd_parts)
        er = er - sk_flat(plan, rp, upd_flat).astype(jnp.float32)
        state["sk_mom"] = mom
        state["sk_err"] = er
        update = unpack_tree(plan, upd_flat, cast=False)
        params, state["opt"] = apply_update(cfg.server, state["opt"], params, update)

    elif cfg.name == "onebit_adam":
        mean_delta = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas)
        warm = rnd < cfg.onebit_warmup

        def warm_branch(op):
            params_, state_ = op
            p2, opt2 = apply_update(cfg.server, state_["opt"], params_, mean_delta)
            # track variance to freeze at warmup end
            vf = jax.tree.map(
                lambda v, u: cfg.server.beta2 * v + (1 - cfg.server.beta2) * u * u,
                state_["v_frozen"], mean_delta)
            return p2, {**state_, "opt": opt2, "v_frozen": vf}

        def comp_branch(op):
            params_, state_ = op
            # per-client sign compression with EF
            a = jax.tree.map(lambda e, d: e + d, state_["err"], deltas)
            c = jax.tree.map(lambda t: jax.vmap(
                lambda v: sign_quant(v.reshape(-1)).reshape(v.shape))(t), a)
            err2 = jax.tree.map(lambda x, y: x - y, a, c)
            u = jax.tree.map(lambda t: jnp.mean(t, axis=0), c)
            m2 = jax.tree.map(
                lambda m, ui: cfg.server.beta1 * m + (1 - cfg.server.beta1) * ui,
                state_["opt"]["m"], u)
            dirn = jax.tree.map(
                lambda m, v: m / (jnp.sqrt(v) + cfg.server.eps),
                m2, state_["v_frozen"])
            p2 = jax.tree.map(lambda p, d: (p - cfg.server.lr * d).astype(p.dtype),
                              params_, dirn)
            opt2 = {**state_["opt"], "m": m2,
                    "step": state_["opt"]["step"] + 1}
            return p2, {**state_, "opt": opt2, "err": err2}

        params, state = jax.lax.cond(warm, warm_branch, comp_branch,
                                     (params, state))

    elif cfg.name == "marina":
        # gradient-difference compression; clients evaluate grads at x_t and
        # x_{t-1} on the same minibatch (K=1 semantics: delta/eta = grad)
        grads = jax.tree.map(lambda d: d / eta, deltas)     # (G, shape)
        scfg = SAFLConfig(client_lr=cfg.client_lr, local_steps=cfg.local_steps)
        prev_p = state["prev_params"]
        prev_deltas, _ = jax.vmap(
            lambda mb: client_delta(scfg, loss_fn, prev_p, mb, eta))(batch)
        prev_grads = jax.tree.map(lambda d: d / eta, prev_deltas)
        full_round = jax.random.bernoulli(key, cfg.marina_p)

        def full_fn(_):
            return jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)

        def diff_fn(_):
            def comp_leaf(i, diff_flat):  # (G, n)
                k = max(1, int(diff_flat.shape[1] * cfg.topk_ratio))
                return jax.vmap(lambda g, v: randk_unbiased(
                    jax.random.fold_in(jax.random.fold_in(key, i), g), v, k))(
                        jnp.arange(G), diff_flat)
            diffs = jax.tree.map(lambda g, pg: g - pg, grads, prev_grads)
            leaves, treedef = jax.tree_util.tree_flatten(diffs)
            out = []
            for i, l in enumerate(leaves):
                c = comp_leaf(i, l.reshape(l.shape[0], -1)).reshape(l.shape)
                out.append(jnp.mean(c, axis=0))
            q = jax.tree_util.tree_unflatten(treedef, out)
            return jax.tree.map(lambda g0, qi: g0 + qi, state["g"], q)

        g_new = jax.lax.cond(full_round, full_fn, diff_fn, None)
        state["g"] = g_new
        state["prev_params"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        params, state["opt"] = apply_update(cfg.server, state["opt"], params, g_new)

    else:
        raise ValueError(f"unknown baseline {cfg.name}")

    state["round"] = rnd + 1
    return params, state, metrics


def uplink_bits(cfg: BaselineConfig, params: Pytree) -> int:
    """Approximate per-client uplink bits per round, for Table 1 parity."""
    n = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
    if cfg.name in ("fedavg", "fedopt"):
        return n * 32
    if cfg.name in ("topk_ef", "cdadam"):
        k = int(n * cfg.topk_ratio)
        return k * (32 + 32)  # value + index
    if cfg.name == "cocktail":
        k = int(n * cfg.topk_ratio)
        return k * (1 + 32)   # sign bit + index
    if cfg.name == "fetchsgd":
        from repro.core.sketch import total_sketch_bits
        return total_sketch_bits(cfg.sketch, params)
    if cfg.name == "onebit_adam":
        return n * 1
    if cfg.name == "marina":
        k = int(n * cfg.topk_ratio)
        return int(cfg.marina_p * n * 32 + (1 - cfg.marina_p) * k * 64)
    raise ValueError(cfg.name)
