"""Communication-efficient FL baselines the paper compares against (§5, Table 1).

All baselines share the SAFL round interface:
    round(cfg, loss_fn, params, state, batch(G, K, mb, ...), key)
        -> (params, state, metrics)

Implemented:
  * ``fedavg``      -- plain local-SGD averaging (uncompressed, server SGD)
  * ``fedopt``      -- uncompressed adaptive server (Reddi et al. 2020); the
                       paper's "ambient dimension" reference (in safl.py)
  * ``topk_ef``     -- Top-K sparsification + client error feedback
                       (Stich et al. 2018)
  * ``fetchsgd``    -- Count-Sketch uplink, server sketch-momentum + sketch
                       error accumulation + heavy-hitter Top-K unsketch
                       (Rothchild et al. 2020)
  * ``onebit_adam`` -- Adam warmup, then frozen-variance sign compression
                       with error feedback (Tang et al. 2021)
  * ``marina``      -- unbiased compressed gradient differences with periodic
                       full sync (Gorbunov et al. 2021a), Rand-K compressor
  * ``cocktail``    -- simplified CocktailSGD (Wang et al. 2023): Rand-K then
                       sign quantization, wrapped in error feedback.  (The
                       full pipeline also stages top-k; we document this
                       simplification in EXPERIMENTS.md.)

These run in the paper-scale simulation path (C clients on one device) for
the convergence benchmarks; ``fedopt`` and ``safl`` additionally run on the
production mesh where their O(d) vs O(b) collectives are rooflined.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.adaptive import AdaConfig, apply_update, init_opt_state
from repro.core.packed import (derive_round_params, desk_flat,
                               make_packing_plan, pack_tree, sk_flat,
                               sk_packed_clients, unpack_tree)
from repro.core.safl import (SAFLConfig, client_delta, mask_weights,
                             masked_mean, masked_mean_tree, masked_where_tree)
from repro.core.sketch import SketchConfig

Pytree = Any
LossFn = Callable[[Pytree, Any], jax.Array]


@dataclasses.dataclass(frozen=True)
class BaselineConfig:
    name: str = "fedavg"
    client_lr: float = 0.1
    local_steps: int = 1
    server: AdaConfig = AdaConfig(name="sgd", lr=1.0)
    remat_local: bool = True        # jax.checkpoint around local grads
    # compression knobs
    topk_ratio: float = 0.01        # fraction of coords kept (topk/randk)
    sketch: SketchConfig = SketchConfig(kind="countsketch", ratio=0.01)
    fetchsgd_momentum: float = 0.9
    fetchsgd_shrink: float = 0.0    # heavy-hitter shrinkage; 0 = auto (b/n)
    onebit_warmup: int = 10
    marina_p: float = 0.1           # prob of full-gradient sync round
    seed_tag: int = 0

    def _safl(self) -> SAFLConfig:
        return SAFLConfig(client_lr=self.client_lr,
                          local_steps=self.local_steps,
                          remat_local=self.remat_local)


# --------------------------------------------------------------------------
# compressors (per flat vector)
# --------------------------------------------------------------------------

def kth_largest_abs(v: jax.Array, k: int) -> jax.Array:
    """Exact k-th largest of |v| WITHOUT a sort.

    ``lax.top_k`` lowers to a full variadic sort on XLA:CPU (~60ms for 90k
    floats), which made top-k the dominant cost of the topk_ef/fetchsgd
    rounds.  Non-negative f32 values order exactly like their int32 bit
    patterns, so a 32-step binary search on the bit value -- each step one
    O(n) count -- finds the identical threshold ``top_k(|v|, k)[0][-1]``.
    """
    xi = jax.lax.bitcast_convert_type(jnp.abs(v).astype(jnp.float32),
                                      jnp.int32)
    k = jnp.asarray(k, jnp.int32)

    def body(_, lh):
        lo, hi = lh
        mid = lo + (hi - lo + 1) // 2
        ok = jnp.sum(xi >= mid) >= k           # pred monotone in mid
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    lo, _ = jax.lax.fori_loop(0, 32, body, (jnp.min(xi), jnp.max(xi)))
    return jax.lax.bitcast_convert_type(lo, jnp.float32)


def topk_mask(v: jax.Array, k: int) -> jax.Array:
    """Dense mask keeping the k largest-|.| entries (biased, contractive).
    Threshold via ``kth_largest_abs`` (sort-free; identical selection)."""
    k = max(1, min(k, v.shape[0]))
    thresh = kth_largest_abs(v, k)
    return jnp.where(jnp.abs(v) >= thresh, v, 0.0)


def randk_unbiased(key: jax.Array, v: jax.Array, k: int) -> jax.Array:
    """Unbiased Rand-K: keep k random coords scaled by n/k (omega = n/k - 1)."""
    n = v.shape[0]
    k = max(1, min(k, n))
    idx = jax.random.choice(key, n, (k,), replace=False)
    mask = jnp.zeros((n,), v.dtype).at[idx].set(1.0)
    return v * mask * (n / k)


def randp_unbiased(key: jax.Array, v: jax.Array, p: float) -> jax.Array:
    """Unbiased Bernoulli Rand-p: keep each coord w.p. ``p``, scale by 1/p.

    Same compression omega as exact Rand-K at p = k/n (1/p - 1 = n/k - 1),
    but O(n) -- ``jax.random.choice(replace=False)`` materializes a full
    random permutation (an O(n log n) sort on CPU) per call, which dominated
    the marina round."""
    mask = jax.random.bernoulli(key, p, v.shape)
    return jnp.where(mask, v / p, 0.0)


def sign_quant(v: jax.Array) -> jax.Array:
    """1-bit sign quantization with l1 scale (1bit-Adam / signSGD style)."""
    return jnp.sign(v) * jnp.mean(jnp.abs(v))


def _per_leaf(fn, tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return jax.tree_util.tree_unflatten(
        treedef, [fn(i, l.reshape(-1)).reshape(l.shape)
                  for i, l in enumerate(leaves)])


# --------------------------------------------------------------------------
# state
# --------------------------------------------------------------------------

def init_baseline_state(cfg: BaselineConfig, params: Pytree, num_clients: int,
                        plan=None) -> dict:
    f32 = lambda t: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)
    state = {"opt": init_opt_state(cfg.server, params),
             "round": jnp.zeros((), jnp.int32)}
    if cfg.name in ("topk_ef", "onebit_adam", "cocktail", "cdadam"):
        # per-client error memories
        state["err"] = jax.tree.map(
            lambda p: jnp.zeros((num_clients,) + p.shape, jnp.float32), params)
    if cfg.name == "fetchsgd":
        # sketch-space accumulators live in the packed (b_total,) payload
        if plan is None:
            plan = make_packing_plan(cfg.sketch, params)
        state["sk_mom"] = jnp.zeros((plan.b_total,), jnp.float32)
        state["sk_err"] = jnp.zeros((plan.b_total,), jnp.float32)
    if cfg.name == "marina":
        state["g"] = f32(params)
        # explicit copy: ``astype`` is a no-op for f32 params, and aliasing
        # prev_params to params breaks donation (same buffer donated twice)
        state["prev_params"] = jax.tree.map(
            lambda p: jnp.array(p, jnp.float32, copy=True), params)
    if cfg.name == "onebit_adam":
        state["v_frozen"] = f32(params)
    return state


def _deltas_and_losses(cfg: BaselineConfig, loss_fn, params, batch, eta):
    scfg = cfg._safl()
    return jax.vmap(lambda mb: client_delta(scfg, loss_fn, params, mb, eta))(batch)


# --------------------------------------------------------------------------
# rounds
# --------------------------------------------------------------------------

def baseline_round(cfg: BaselineConfig, loss_fn: LossFn, params: Pytree,
                   state: dict, batch: Pytree, key: jax.Array, *,
                   plan=None, part_mask=None,
                   telemetry=None) -> tuple[Pytree, dict, dict]:
    """One baseline round.  PURELY FUNCTIONAL: the input ``state`` dict is
    never mutated -- a fresh dict is returned each round, which is what makes
    this a safe ``lax.scan`` carry and a safe donation target in the
    multi-round driver (an aliased in-place update would read freed buffers).

    ``plan`` (optional) is the static packing layout, built once by
    multi-round callers as in ``safl_round``.  ``part_mask`` (optional, (G,))
    restricts the server aggregation to the round's sampled cohort
    (repro.fed): unsampled clients transmit nothing -- their error-feedback
    memories stay frozen and the server mean divides by the cohort size.  An
    all-ones mask is bitwise the full-participation path.  ``telemetry``
    (static ``repro.obs.Telemetry``) adds probe scalars to the metrics --
    for baselines: cohort-mean delta norm, effective cohort, moment norms
    and, where the variant carries one (topk_ef / cocktail / cdadam /
    onebit_adam ``err``, fetchsgd ``sk_err``), the error-feedback memory
    norm -- the EF-drift observable of the compressed-Adam literature.
    """
    eta = jnp.asarray(cfg.client_lr, jnp.float32)
    rnd = state["round"]
    prev_deltas = None
    if cfg.name == "marina":
        # MARINA evaluates grads at BOTH x_t and x_{t-1} on the same
        # minibatch.  Fuse the two evaluations into one vmapped pass over
        # stacked parameters: same math, half the op-dispatch overhead of
        # two sequential client_delta sweeps.
        scfg = cfg._safl()
        stacked = jax.tree.map(lambda a, b: jnp.stack([a, b.astype(a.dtype)]),
                               params, state["prev_params"])
        d2, l2 = jax.vmap(lambda p: jax.vmap(
            lambda mb: client_delta(scfg, loss_fn, p, mb, eta))(batch)
        )(stacked)
        deltas = jax.tree.map(lambda x: x[0], d2)
        prev_deltas = jax.tree.map(lambda x: x[1], d2)
        losses = l2[0]
    else:
        deltas, losses = _deltas_and_losses(cfg, loss_fn, params, batch, eta)
    metrics = {"loss": masked_mean(losses, part_mask)}
    G = jax.tree.leaves(deltas)[0].shape[0]

    if cfg.name == "fedavg" or cfg.name == "fedopt":
        update = masked_mean_tree(deltas, part_mask)
        params, opt = apply_update(cfg.server, state["opt"], params, update)
        state = {**state, "opt": opt}

    elif cfg.name in ("topk_ef", "cocktail", "cdadam"):
        # packed layout (DESIGN.md §4): error memory + delta flattened into
        # one (G, d_total) buffer; the compressor runs ONCE on the packed
        # vector (global top-k / rand-k, the canonical formulation) instead
        # of a per-leaf loop.
        if plan is None:
            plan = make_packing_plan(cfg.sketch, params)
        a2 = jax.vmap(lambda t: pack_tree(plan, t))(
            jax.tree.map(lambda e, d: e + d, state["err"], deltas))
        k = max(1, int(plan.d_total * cfg.topk_ratio))
        if cfg.name == "cocktail":
            def comp_one(g, v):
                kk = jax.random.fold_in(key, g)
                # biased Bernoulli Rand-p, p = k/n (expected-k; EF absorbs
                # the bias either way).  Exact Rand-K needed a full random
                # permutation -- an O(n log n) sort that dominated the round
                # on CPU; the Bernoulli draw is one O(n) PRNG pass.
                mask = jax.random.bernoulli(kk, k / v.shape[0], v.shape)
                sparse = jnp.where(mask, v, 0.0)
                # sign-quantize the survivors (scale = mean |.| over kept)
                kept = jnp.maximum(jnp.sum(mask), 1)
                scale = jnp.sum(jnp.abs(sparse)) / kept
                return jnp.sign(sparse) * scale
            comp = jax.vmap(comp_one)(jnp.arange(G), a2)
        else:
            comp = jax.vmap(lambda v: topk_mask(v, k))(a2)
        err_flat = a2 - comp
        if part_mask is not None:
            # unsampled clients never compressed/transmitted: their error
            # memory is untouched this round
            old_flat = jax.vmap(lambda t: pack_tree(plan, t))(state["err"])
            sel = mask_weights(part_mask)
            err_flat = jnp.where(sel[:, None] > 0, err_flat, old_flat)
        err = jax.vmap(lambda f: unpack_tree(plan, f, cast=False))(err_flat)
        update = unpack_tree(plan, masked_mean(comp, part_mask), cast=False)
        params, opt = apply_update(cfg.server, state["opt"], params, update)
        state = {**state, "err": err, "opt": opt}

    elif cfg.name == "fetchsgd":
        # NOTE: canonical FetchSGD keeps ONE fixed sketch so momentum/error
        # accumulate coherently -- but that variant provably relies on the
        # heavy-hitter assumption (paper Table 1 note (A)); on dense
        # (non-heavy-hitter) gradients the fixed-hash aliasing is a positive
        # feedback loop and it diverges (we verified: see EXPERIMENTS.md
        # §Baselines).  We therefore re-key the sketch each round: the
        # sketch-space accumulators then act as unbiased compressed momentum
        # + error smoothing, which is stable without heavy hitters.
        #
        # The packed engine (DESIGN.md §4) sketches all clients x all leaves
        # in one fused pass; per-leaf key derivation (fold_in on the leaf
        # index) is identical to the old per-leaf loop, so with
        # cs_hash="independent" trajectories match the pre-packed code
        # exactly (the default "balanced" family is a different -- equally
        # valid -- count-sketch operator).  Momentum/error accumulate in
        # the (b_total,) payload.
        if plan is None:
            plan = make_packing_plan(cfg.sketch, params)
        rp = derive_round_params(plan, key)
        # clients sketch; server averages sketches (mergeable) -- over the
        # sampled cohort only under partial participation
        sks = sk_packed_clients(plan, rp, deltas)           # (G, b_total)
        s_mean = masked_mean(sks.astype(jnp.float32), part_mask)
        mom = cfg.fetchsgd_momentum * state["sk_mom"] + s_mean
        er = state["sk_err"] + mom
        dense = desk_flat(plan, rp, er)                     # unsketch error acc
        # top-k selection on a desketch picks upward-biased coordinates;
        # shrink by ~b/n so the applied mass matches the true signal
        # (without this the EF loop is a positive feedback on dense,
        # non-heavy-hitter gradients -- see EXPERIMENTS.md §Baselines)
        upd_parts = []
        for op in plan.ops:
            dvec = dense[op.in_off:op.in_off + op.n]
            k = max(1, int(op.n * cfg.topk_ratio))
            shrink = cfg.fetchsgd_shrink or min(1.0, op.b / op.n)
            upd_parts.append(topk_mask(dvec, k) * shrink)   # heavy hitters
        upd_flat = jnp.concatenate(upd_parts)
        er = er - sk_flat(plan, rp, upd_flat).astype(jnp.float32)
        update = unpack_tree(plan, upd_flat, cast=False)
        params, opt = apply_update(cfg.server, state["opt"], params, update)
        state = {**state, "sk_mom": mom, "sk_err": er, "opt": opt}

    elif cfg.name == "onebit_adam":
        mean_delta = masked_mean_tree(deltas, part_mask)
        warm = rnd < cfg.onebit_warmup

        def warm_branch(op):
            params_, state_ = op
            p2, opt2 = apply_update(cfg.server, state_["opt"], params_, mean_delta)
            # track variance to freeze at warmup end
            vf = jax.tree.map(
                lambda v, u: cfg.server.beta2 * v + (1 - cfg.server.beta2) * u * u,
                state_["v_frozen"], mean_delta)
            return p2, {**state_, "opt": opt2, "v_frozen": vf}

        def comp_branch(op):
            params_, state_ = op
            # per-client sign compression with EF
            a = jax.tree.map(lambda e, d: e + d, state_["err"], deltas)
            c = jax.tree.map(lambda t: jax.vmap(
                lambda v: sign_quant(v.reshape(-1)).reshape(v.shape))(t), a)
            err2 = masked_where_tree(part_mask,
                                     jax.tree.map(lambda x, y: x - y, a, c),
                                     state_["err"])
            u = masked_mean_tree(c, part_mask)
            m2 = jax.tree.map(
                lambda m, ui: cfg.server.beta1 * m + (1 - cfg.server.beta1) * ui,
                state_["opt"]["m"], u)
            dirn = jax.tree.map(
                lambda m, v: m / (jnp.sqrt(v) + cfg.server.eps),
                m2, state_["v_frozen"])
            p2 = jax.tree.map(lambda p, d: (p - cfg.server.lr * d).astype(p.dtype),
                              params_, dirn)
            opt2 = {**state_["opt"], "m": m2,
                    "step": state_["opt"]["step"] + 1}
            return p2, {**state_, "opt": opt2, "err": err2}

        params, state = jax.lax.cond(warm, warm_branch, comp_branch,
                                     (params, state))

    elif cfg.name == "marina":
        # gradient-difference compression (grads at x_t / x_{t-1} computed
        # by the fused two-point pass above; K=1 semantics: delta/eta = grad)
        grads = jax.tree.map(lambda d: d / eta, deltas)     # (G, shape)
        prev_grads = jax.tree.map(lambda d: d / eta, prev_deltas)
        full_round = jax.random.bernoulli(key, cfg.marina_p)
        if plan is None:
            plan = make_packing_plan(cfg.sketch, params)

        def full_fn(_):
            return masked_mean_tree(grads, part_mask)

        def diff_fn(_):
            # packed layout: one (G, d_total) buffer, one Bernoulli Rand-p
            # pass per client (unbiased, omega = 1/p - 1 = n/k - 1) instead
            # of a per-leaf loop of permutation-based Rand-K draws
            diffs = jax.tree.map(lambda g, pg: g - pg, grads, prev_grads)
            flat = jax.vmap(lambda t: pack_tree(plan, t))(diffs)
            comp = jax.vmap(lambda g, v: randp_unbiased(
                jax.random.fold_in(key, g), v, cfg.topk_ratio))(
                    jnp.arange(G), flat)
            q = unpack_tree(plan, masked_mean(comp, part_mask), cast=False)
            return jax.tree.map(lambda g0, qi: g0 + qi, state["g"], q)

        g_new = jax.lax.cond(full_round, full_fn, diff_fn, None)
        prev = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        params, opt = apply_update(cfg.server, state["opt"], params, g_new)
        state = {**state, "g": g_new, "prev_params": prev, "opt": opt}

    else:
        raise ValueError(f"unknown baseline {cfg.name}")

    new_state = {**state, "round": rnd + 1}
    if telemetry is not None:
        # no update/residual probes here: most baselines apply a biased
        # compressed update, so "desketch residual" is not their observable;
        # delta/EF/moment norms and the cohort are
        from repro.obs.telemetry import telemetry_probes
        metrics.update(telemetry_probes(
            telemetry, deltas=deltas, part_mask=part_mask, state=new_state))
    return params, new_state, metrics


def uplink_bits(cfg: BaselineConfig, params: Pytree) -> int:
    """Approximate per-client uplink bits per round, for Table 1 parity."""
    n = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
    if cfg.name in ("fedavg", "fedopt"):
        return n * 32
    if cfg.name in ("topk_ef", "cdadam"):
        k = int(n * cfg.topk_ratio)
        return k * (32 + 32)  # value + index
    if cfg.name == "cocktail":
        k = int(n * cfg.topk_ratio)
        return k * (1 + 32)   # sign bit + index
    if cfg.name == "fetchsgd":
        from repro.core.sketch import total_sketch_bits
        return total_sketch_bits(cfg.sketch, params)
    if cfg.name == "onebit_adam":
        return n * 1
    if cfg.name == "marina":
        k = int(n * cfg.topk_ratio)
        return int(cfg.marina_p * n * 32 + (1 - cfg.marina_p) * k * 64)
    raise ValueError(cfg.name)
