"""Intrinsic dimension of the loss Hessian (paper Def. 3.1, Fig. 5).

    I = sum_i |lambda_i| / max_i |lambda_i|

The SAFL *algorithm* never computes this -- it appears only in the theory --
but the paper validates Assumption 4 empirically (Appendix D, Fig. 5) with
stochastic Lanczos on Hessian-vector products.  We reproduce that
verification: HVPs via forward-over-reverse ``jax.jvp(jax.grad(...))``,
lambda_max via Lanczos, trace(|H|) via stochastic Lanczos quadrature (SLQ).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

Pytree = Any


def make_hvp(loss_fn: Callable, params: Pytree, batch: Any):
    """Returns (matvec on flat vectors, dim)."""
    flat0, unravel = ravel_pytree(params)
    d = flat0.shape[0]

    def loss_flat(flat):
        return loss_fn(unravel(flat), batch)

    def matvec(v):
        return jax.jvp(jax.grad(loss_flat), (flat0,), (v,))[1]

    return jax.jit(matvec), d


def lanczos(matvec: Callable, dim: int, num_iters: int, key: jax.Array,
            v0: np.ndarray | None = None):
    """Lanczos tridiagonalization with full reorthogonalization.

    Returns (ritz_values, ritz_weights) where weights are the squared first
    components of the tridiagonal eigenvectors (for SLQ quadrature).
    """
    if v0 is None:
        v0 = np.asarray(jax.random.normal(key, (dim,)), np.float64)
    v = v0 / np.linalg.norm(v0)
    V = [v]
    alphas, betas = [], []
    beta = 0.0
    v_prev = np.zeros(dim)
    for _ in range(num_iters):
        w = np.asarray(matvec(jnp.asarray(v, jnp.float32)), np.float64)
        alpha = float(v @ w)
        w = w - alpha * v - beta * v_prev
        # full reorthogonalization (twice for stability)
        for _ in range(2):
            for u in V:
                w = w - (u @ w) * u
        beta = float(np.linalg.norm(w))
        alphas.append(alpha)
        if beta < 1e-10 or len(alphas) == num_iters:
            break
        v_prev, v = v, w / beta
        V.append(v)
        betas.append(beta)
    T = np.diag(alphas) + np.diag(betas, 1) + np.diag(betas, -1)
    evals, evecs = np.linalg.eigh(T)
    weights = evecs[0, :] ** 2
    return evals, weights


def hessian_spectrum_slq(loss_fn: Callable, params: Pytree, batch: Any,
                         num_iters: int = 30, num_probes: int = 4,
                         key: jax.Array | None = None):
    """Approximate (eigenvalue nodes, density weights) of the Hessian
    spectrum via SLQ -- the quantity plotted in paper Fig. 5."""
    key = jax.random.key(0) if key is None else key
    matvec, d = make_hvp(loss_fn, params, batch)
    nodes, weights = [], []
    for p in range(num_probes):
        ev, w = lanczos(matvec, d, num_iters, jax.random.fold_in(key, p))
        nodes.append(ev)
        weights.append(w / num_probes)
    return np.concatenate(nodes), np.concatenate(weights), d


def intrinsic_dimension(loss_fn: Callable, params: Pytree, batch: Any,
                        num_iters: int = 30, num_probes: int = 4,
                        key: jax.Array | None = None) -> dict:
    """Estimate I = trace(|H|) / lambda_max and related diagnostics."""
    nodes, weights, d = hessian_spectrum_slq(
        loss_fn, params, batch, num_iters, num_probes, key)
    trace_abs = float(d * np.sum(weights * np.abs(nodes)))
    lam_max = float(np.max(np.abs(nodes)))
    return {
        "intrinsic_dim": trace_abs / max(lam_max, 1e-12),
        "lambda_max": lam_max,
        "trace_abs": trace_abs,
        "ambient_dim": d,
        "nodes": nodes,
        "weights": weights,
    }
