"""Streamed per-chunk metric shards + run event log (DESIGN.md §11).

Closes the PR-4 open item: instead of accumulating every chunk's stacked
metric history in host memory for the lifetime of a run, the scanned
drivers hand each chunk's history to a ``ShardWriter``, which

* starts the device->host copy ASYNCHRONOUSLY (``copy_to_host_async`` on
  every history leaf, so the transfer overlaps the next chunk's dispatch),
* appends one JSONL *shard* per chunk (``metrics-00000.jsonl``, one row
  per round: ``{"kind": "metrics", "t": <absolute round>, "loss": ...,
  <probe/counter keys>}``), and
* keeps only O(1) running aggregates (per-key sum/count/last) so an
  end-of-run summary needs no replay.

Because every per-round stream is a pure function of the absolute round
index, the concatenated shard rows of a chunked run are identical to a
single-dispatch run's -- shard boundaries are an I/O artifact, not a
numeric one (tests/test_obs.py pins this).

``events.jsonl`` carries the non-metric streams in the same directory:
wall-time spans per chunk (``{"kind": "span", "t0", "t1", "seconds",
"compile"}`` -- ``compile: true`` marks the first use of a chunk-length
executable, so compile and steady-state cost separate cleanly) and the
supervisor's recovery events (``{"kind": "recovery", "retry", "t_fault",
"t_resume", "depth", "reason", "rekey"}``).  Under the rollback supervisor
a retried span re-emits its rounds in NEW shards; recovery events mark the
rollbacks, and readers resolve duplicate ``t`` values as last-wins.

``tools/check_telemetry.py`` validates the formats; ``tools/obs_report.py``
renders a run directory.
"""

from __future__ import annotations

import json
import os
from typing import Any

import numpy as np

Pytree = Any


def host_fetch(tree: Pytree) -> Pytree:
    """Device->host copy of a metric history, transfer started async on
    every leaf before the first blocking read."""
    import jax
    for x in jax.tree.leaves(tree):
        if hasattr(x, "copy_to_host_async"):
            x.copy_to_host_async()
    return jax.tree.map(np.asarray, tree)


def span_stats(per_round_seconds) -> dict:
    """p50/p95 (in us) over a set of per-round wall-time samples -- the
    summary benchmarks/run.py pins next to each ``_scan`` total."""
    a = np.asarray(list(per_round_seconds), np.float64)
    if a.size == 0:
        return {}
    return {"p50_us": float(np.percentile(a, 50) * 1e6),
            "p95_us": float(np.percentile(a, 95) * 1e6)}


class ShardWriter:
    """Append-only JSONL shard writer for one run directory.

    ``write_chunk(t0, hist)`` takes a chunk's stacked history (dict of
    (n,) arrays, already on host -- pair with ``host_fetch``) and writes
    one metrics shard; ``write_span``/``write_event`` append to the shared
    ``events.jsonl``.  ``summary()`` returns the O(1) running aggregates.
    """

    def __init__(self, out_dir: str):
        os.makedirs(out_dir, exist_ok=True)
        self.out_dir = out_dir
        self.rounds = 0
        self.recoveries = 0
        self._shard = 0
        self._events_path = os.path.join(out_dir, "events.jsonl")
        self._sums: dict[str, tuple[float, int]] = {}
        self._last: dict[str, float] = {}

    def write_chunk(self, t0: int, hist: dict) -> str:
        keys = sorted(hist)
        if not keys:
            return ""
        n = int(np.asarray(hist[keys[0]]).shape[0])
        path = os.path.join(self.out_dir, f"metrics-{self._shard:05d}.jsonl")
        cols = {k: np.asarray(hist[k], np.float64) for k in keys}
        with open(path, "w") as f:
            for i in range(n):
                row = {"kind": "metrics", "t": int(t0) + i}
                for k in keys:
                    row[k] = float(cols[k][i])
                f.write(json.dumps(row) + "\n")
        self._shard += 1
        self.rounds += n
        for k in keys:
            tot, cnt = self._sums.get(k, (0.0, 0))
            self._sums[k] = (tot + float(np.nansum(cols[k])),
                             cnt + int(cols[k].size))
            self._last[k] = float(cols[k][-1])
        return path

    def write_span(self, t0: int, t1: int, seconds: float,
                   compile: bool = False) -> None:
        self.write_event("span", t0=int(t0), t1=int(t1),
                         seconds=float(seconds), compile=bool(compile))

    def write_event(self, kind: str, **fields) -> None:
        if kind == "recovery":
            self.recoveries += 1
        with open(self._events_path, "a") as f:
            f.write(json.dumps({"kind": kind, **fields}) + "\n")

    def mean(self, key: str):
        tot, cnt = self._sums.get(key, (0.0, 0))
        return tot / cnt if cnt else None

    def total(self, key: str):
        return self._sums.get(key, (None, 0))[0]

    def last(self, key: str):
        return self._last.get(key)

    def summary(self) -> dict:
        return {"rounds": self.rounds,
                "shards": self._shard,
                "final_loss": self.last("loss"),
                "mean_residual": self.mean("residual"),
                "total_rejected": self.total("n_rejected"),
                "recoveries": self.recoveries}


def format_summary(s: dict) -> str:
    """Compact end-of-run line (examples/train_lm.py prints this)."""
    parts = [f"rounds={s.get('rounds', 0)}"]
    if s.get("final_loss") is not None:
        parts.append(f"final_loss={s['final_loss']:.4f}")
    if s.get("mean_residual") is not None:
        parts.append(f"mean_residual={s['mean_residual']:.4f}")
    rej = s.get("total_rejected")
    parts.append(f"rejected={0.0 if rej is None else rej:.0f}")
    parts.append(f"retries={s.get('recoveries', 0)}")
    return "telemetry: " + "  ".join(parts)
