"""Run manifests: one JSON record of everything a telemetry run ran under
(DESIGN.md §11).

A manifest pins the run's software stack (jax/jaxlib versions, backend,
device count), its configuration (model/algorithm config, sketch family,
mesh topology) and -- when a committed BENCH_sketch.json is reachable --
the guard's ``*.final_loss`` convergence pins in force at run time, so a
shard directory is interpretable long after the code moved on.

``tools/check_telemetry.py`` validates ``REQUIRED_KEYS``;
``tools/obs_report.py`` renders the manifest at the top of its report.
"""

from __future__ import annotations

import dataclasses
import json
import os
import platform
import sys
from typing import Any

# every manifest must carry these (schema contract of check_telemetry)
REQUIRED_KEYS = ("kind", "run", "jax", "jaxlib", "backend", "device_count")


def _jsonable(x: Any) -> Any:
    """Best-effort coercion of configs (dataclasses, numpy scalars, pytrees
    of plain containers) into JSON-serializable values."""
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        return {f.name: _jsonable(getattr(x, f.name))
                for f in dataclasses.fields(x)}
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (str, int, float, bool)) or x is None:
        return x
    if hasattr(x, "item") and getattr(x, "ndim", 1) == 0:
        return x.item()
    return repr(x)


def write_manifest(out_dir: str, *, run: str, config=None, mesh=None,
                   topology: str | None = None, sketch=None,
                   guard_pins: str | None = "BENCH_sketch.json",
                   extra: dict | None = None) -> str:
    """Write ``out_dir/manifest.json``; returns its path.

    ``mesh`` is a ``jax.sharding.Mesh`` (axis sizes are recorded),
    ``sketch`` a ``SketchConfig``, ``config`` any dataclass/dict of run
    parameters.  ``guard_pins`` names a BENCH_sketch.json whose
    ``*.final_loss`` keys are embedded when the file exists (pass ``None``
    to skip)."""
    import jax
    try:
        import jaxlib
        jaxlib_ver = jaxlib.__version__
    except Exception:  # pragma: no cover - jaxlib always ships with jax
        jaxlib_ver = ""
    man: dict[str, Any] = {
        "kind": "manifest",
        "run": run,
        "jax": jax.__version__,
        "jaxlib": jaxlib_ver,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "argv": list(sys.argv),
    }
    if topology is not None:
        man["topology"] = topology
    if mesh is not None:
        man["mesh"] = {str(a): int(n) for a, n in dict(mesh.shape).items()}
    if sketch is not None:
        man["sketch"] = _jsonable(sketch)
    if config is not None:
        man["config"] = _jsonable(config)
    if guard_pins and os.path.exists(guard_pins):
        try:
            with open(guard_pins) as f:
                rows = json.load(f)
            pins = {k: v for k, v in rows.items()
                    if k.endswith(".final_loss")}
            if pins:
                man["guard_pins"] = pins
        except (OSError, json.JSONDecodeError, AttributeError):
            pass
    if extra:
        man.update(_jsonable(extra))
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "manifest.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(man, f, indent=2, sort_keys=True)
    os.replace(tmp, path)
    return path
