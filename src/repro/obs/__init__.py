"""Observability layer: in-graph probes, streamed metric shards, run
manifests, and the profiling report (DESIGN.md §11)."""

from repro.obs.manifest import REQUIRED_KEYS, write_manifest
from repro.obs.shards import (ShardWriter, format_summary, host_fetch,
                              span_stats)
from repro.obs.telemetry import (PROBE_KEYS, Telemetry, effective_cohort,
                                 state_norms, telemetry_probes, tree_norm)

__all__ = [
    "PROBE_KEYS", "REQUIRED_KEYS", "ShardWriter", "Telemetry",
    "effective_cohort", "format_summary", "host_fetch", "span_stats",
    "state_norms", "telemetry_probes", "tree_norm", "write_manifest",
]
