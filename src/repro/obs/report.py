"""Profiling report over a telemetry run directory (DESIGN.md §11).

``render(run_dir)`` loads the manifest, metric shards and event log written
by ``obs.shards``/``obs.manifest`` and produces a text report with four
sections:

1. manifest summary (stack versions, backend, config, guard pins count),
2. metric summary (rounds, final/mean loss, probe means where present),
3. wall-time spans: per-chunk us/round with the compile chunk split out
   from steady state, plus p50/p95 over the steady-state chunks,
4. (opt-in, ``profile=True``) a roofline/HLO-cost section: the previously
   idle ``launch.roofline`` + ``launch.hlo_costs`` analyses run against a
   freshly compiled bench-scale SAFL scan chunk on the local backend --
   trip-count-weighted FLOPs/bytes/collective bytes and the v5e roofline
   time terms.  (The 512-device dry-run harness ``launch.dryrun`` is NOT
   imported here: it forces a device count at import time, which must never
   leak into a live session.)

``tools/obs_report.py`` is the CLI wrapper.
"""

from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.obs.shards import span_stats


def load_run(run_dir: str) -> dict:
    """Parse a run directory: ``{"manifest": dict, "rows": [dict],
    "events": [dict]}`` (missing pieces come back empty)."""
    manifest = {}
    mpath = os.path.join(run_dir, "manifest.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            manifest = json.load(f)
    rows = []
    for path in sorted(glob.glob(os.path.join(run_dir, "metrics-*.jsonl"))):
        with open(path) as f:
            for line in f:
                if line.strip():
                    rows.append(json.loads(line))
    events = []
    epath = os.path.join(run_dir, "events.jsonl")
    if os.path.exists(epath):
        with open(epath) as f:
            for line in f:
                if line.strip():
                    events.append(json.loads(line))
    return {"manifest": manifest, "rows": rows, "events": events}


def _manifest_lines(man: dict) -> list[str]:
    if not man:
        return ["  (no manifest.json)"]
    lines = [f"  run={man.get('run', '?')}  jax={man.get('jax', '?')}"
             f"  jaxlib={man.get('jaxlib', '?')}"
             f"  backend={man.get('backend', '?')}"
             f"  devices={man.get('device_count', '?')}"]
    if "mesh" in man:
        axes = "x".join(f"{k}={v}" for k, v in man["mesh"].items())
        lines.append(f"  mesh: {axes}  topology={man.get('topology', '-')}")
    if "sketch" in man:
        sk = man["sketch"]
        lines.append(f"  sketch: kind={sk.get('kind', '?')}"
                     f" ratio={sk.get('ratio', '?')}")
    if "guard_pins" in man:
        lines.append(f"  guard pins embedded: {len(man['guard_pins'])}")
    return lines


def _metric_lines(rows: list[dict]) -> list[str]:
    if not rows:
        return ["  (no metric shards)"]
    # last-wins over t: a supervised run re-emits retried spans
    by_t = {r["t"]: r for r in rows if r.get("kind") == "metrics"}
    ts = sorted(by_t)
    lines = [f"  rounds: {len(ts)} (t {ts[0]}..{ts[-1]};"
             f" {len(rows)} shard rows)"]
    keys = sorted({k for r in by_t.values() for k in r}
                  - {"kind", "t"})
    for k in keys:
        vals = np.asarray([by_t[t][k] for t in ts if k in by_t[t]],
                          np.float64)
        if vals.size == 0:
            continue
        lines.append(f"  {k:12s} final={vals[-1]:12.6g}"
                     f"  mean={np.nanmean(vals):12.6g}"
                     f"  max={np.nanmax(vals):12.6g}")
    return lines


def _span_lines(events: list[dict]) -> list[str]:
    spans = [e for e in events if e.get("kind") == "span"]
    if not spans:
        return ["  (no spans recorded)"]
    lines = []
    steady_per_round = []
    for s in spans:
        n = max(1, int(s["t1"]) - int(s["t0"]))
        per_round = s["seconds"] / n
        tag = "compile+run" if s.get("compile") else "steady"
        lines.append(f"  rounds {s['t0']:>5}..{s['t1']:<5}"
                     f" {s['seconds']*1e3:10.1f}ms"
                     f"  {per_round*1e6:10.0f}us/round  [{tag}]")
        if not s.get("compile"):
            steady_per_round.append(per_round)
    st = span_stats(steady_per_round)
    if st:
        lines.append(f"  steady-state per-round: p50={st['p50_us']:.0f}us"
                     f"  p95={st['p95_us']:.0f}us"
                     f"  ({len(steady_per_round)} chunks)")
    recs = [e for e in events if e.get("kind") == "recovery"]
    for r in recs:
        lines.append(f"  recovery: retry {r.get('retry')}"
                     f" fault<{r.get('t_fault')}"
                     f" resume@{r.get('t_resume')}"
                     f" depth={r.get('depth')} ({r.get('reason', '')})")
    return lines


def _profile_lines() -> list[str]:
    """Compile a bench-scale SAFL scan chunk locally and run the roofline /
    trip-weighted HLO-cost analyses on it."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.core.adaptive import AdaConfig
    from repro.core.packed import make_packing_plan
    from repro.core.safl import SAFLConfig, init_safl, safl_round
    from repro.core.sketch import SketchConfig
    from repro.data import BigramLMData, LMDataConfig
    from repro.launch import roofline
    from repro.launch.driver import make_chunk_fn
    from repro.models import ModelConfig, init_params, loss_fn
    from repro.models.model import count_params_analytic

    model = ModelConfig(name="obs-profile", arch_type="dense", num_layers=2,
                        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                        vocab_size=128)
    clients, k, seq, bpc, rounds = 5, 2, 32, 10, 4
    cfg = SAFLConfig(sketch=SketchConfig(kind="countsketch", ratio=0.05,
                                         min_b=8),
                     server=AdaConfig(name="amsgrad", lr=0.01),
                     client_lr=0.5, local_steps=k, remat_local=False)
    data = BigramLMData(LMDataConfig(vocab_size=model.vocab_size,
                                     seq_len=seq, num_clients=clients))
    sampler = data.device_sampler(bpc, k)
    params = init_params(model, jax.random.key(0))
    plan = make_packing_plan(cfg.sketch, params)
    round_fn = functools.partial(safl_round, cfg,
                                 lambda p, b: loss_fn(model, p, b), plan=plan)
    chunk = make_chunk_fn(round_fn, sampler, rounds, donate=False)
    compiled = chunk.lower(params, init_safl(cfg, params),
                           sampler.init_state(), jax.random.key(0),
                           jnp.asarray(0, jnp.int32)).compile()

    n_active = count_params_analytic(model, active_only=True)
    tokens = clients * k * (bpc // k) * seq * rounds
    rep = roofline.analyze(
        compiled, arch=model.name, shape=f"{rounds}r", mesh_name="local",
        chips=max(1, jax.device_count()),
        model_flops=6.0 * n_active * tokens,
        note=f"bench-scale safl chunk ({rounds} rounds)")
    lines = [
        f"  program: {rounds}-round scanned SAFL chunk, bench model"
        f" ({n_active/1e3:.0f}k params, sketch ratio {cfg.sketch.ratio})",
        "  " + roofline.format_row(rep),
        f"  flops/device(trip-weighted)={rep.flops_per_device:.3e}"
        f"  hbm_bytes~{rep.bytes_per_device:.3e}"
        f"  collective_bytes={rep.coll_bytes_per_device:.3e}",
    ]
    counts = rep.coll_breakdown.get("counts", {})
    nz = {kk: v for kk, v in counts.items() if v}
    if nz:
        lines.append("  collectives: " +
                     ", ".join(f"{kk}x{v}" for kk, v in sorted(nz.items())))
    else:
        lines.append("  collectives: none (single-device program)")
    lines.append(f"  roofline constants: PEAK={roofline.PEAK_FLOPS:.0e}F/s"
                 f" HBM={roofline.HBM_BW:.0e}B/s ICI={roofline.ICI_BW:.0e}B/s"
                 " (v5e; rescale for other parts)")
    return lines


def render(run_dir: str, profile: bool = True) -> str:
    run = load_run(run_dir)
    out = [f"== telemetry run report: {run_dir} ==", "", "-- manifest --"]
    out += _manifest_lines(run["manifest"])
    out += ["", "-- metrics --"]
    out += _metric_lines(run["rows"])
    out += ["", "-- wall-time spans --"]
    out += _span_lines(run["events"])
    if profile:
        out += ["", "-- roofline / HLO costs (freshly compiled, local"
                " backend) --"]
        try:
            out += _profile_lines()
        except Exception as e:  # report stays usable without the profile
            out.append(f"  profile section unavailable: {e!r}")
    return "\n".join(out) + "\n"
