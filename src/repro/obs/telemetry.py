"""In-graph round telemetry probes (DESIGN.md §11).

A ``Telemetry`` config is STATIC state, threaded into a round function via
``functools.partial`` exactly like ``plan=`` and ``sentinel=`` -- it is not
a pytree and never traced.  When bound, the round computes the selected
probe scalars next to the loss and returns them in its metrics dict; the
scanned drivers stack them into the per-chunk history like any other metric
key, and the streamed shard writer (``obs.shards``) turns them into JSONL
rows.

**Why statically gated.**  PR 6 established that ANY extra scan output --
even a duplicated loss -- shifts XLA's fusion choices, which perturbs f32
reduction orders and therefore trajectories at the ulp level.  Telemetry is
therefore off by default (``telemetry=None`` leaves every round program
bit-identical to today's pinned trajectories) and, when on, defines its own
program family: enabled-path tests pin WITHIN that family (chunk-split
invariance, scan == host loop under the same probes), never across the
on/off boundary.

The probe set (all f32 scalars per round):

* ``delta_norm``   -- l2 norm of the cohort-mean client delta Δ̄,
* ``update_norm``  -- l2 norm of the applied server update desk(sk(Δ̄)),
* ``residual``     -- relative desketch residual ‖Δ̄ − desk(sk(Δ̄))‖ / ‖Δ̄‖,
  the paper's sketch-noise observable (concentrates near sqrt(d/b) for the
  unbiased families; exactly 0 for the uncompressed FedOPT reference),
* ``m_norm`` / ``v_norm`` / ``vhat_norm`` -- server moment norms AFTER the
  round's ADA_OPT step (sketch-noise accumulation in the preconditioner),
* ``ef_norm``      -- error-feedback memory norm for baselines that carry
  one (topk_ef / cocktail / cdadam / onebit_adam ``err``, fetchsgd
  ``sk_err``),
* ``cohort``       -- effective cohort size: clients with weight > 0 in the
  round's aggregation mask AFTER faults/sentinels (``fed.robust``),
* ``clip_frac``    -- fraction of the cohort whose pre-clip delta norm
  exceeded tau (SACFL rounds only; ``core.clipped`` supplies it).

The counter keys PR 6 already emits (``n_dropped`` / ``n_rejected`` /
``diverged``) ride the same metrics dict and need no probe config.

Under the mesh driver (``launch.train``) the Δ̄-based probes are computed
OUTSIDE the sketch shard_map from the sharded global delta tree, so GSPMD
inserts the O(d) reduction collectives they need -- an explicitly opt-in
cost the compressed uplink itself never pays.  Under the staleness buffer
the "update" is the multi-generation merge, so ``residual`` there measures
desketch + staleness deviation, not the pure sketch round trip.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any

# every probe key a telemetry-enabled history/shard row may carry; the
# single source of truth ``launch.driver.HISTORY_KEYS`` builds on this
PROBE_KEYS = ("delta_norm", "update_norm", "residual", "m_norm", "v_norm",
              "vhat_norm", "ef_norm", "cohort", "clip_frac")


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Per-probe static switches.  ``Telemetry()`` enables the full set; a
    probe only appears in the metrics when BOTH its switch is on and the
    round can supply it (e.g. ``clip_frac`` only from SACFL rounds,
    ``ef_norm`` only from baselines with an EF memory)."""
    delta_norm: bool = True
    update_norm: bool = True
    residual: bool = True
    moments: bool = True
    cohort: bool = True
    clip: bool = True


def tree_norm(tree: Pytree) -> jax.Array:
    """Global l2 norm of a pytree (f32 accumulation)."""
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def effective_cohort(part_mask, num_clients: int) -> jax.Array:
    """Clients with aggregation weight > 0 (post faults/sentinels)."""
    from repro.core.safl import mask_weights
    if part_mask is None:
        return jnp.float32(num_clients)
    w = mask_weights(part_mask)
    return jnp.sum((w > 0).astype(jnp.float32))


def state_norms(state) -> dict:
    """Moment/EF-memory norms from a server state dict.

    Reads the known layout keys where present: ``m``/``v``/``vhat`` from
    the ADA_OPT state (possibly nested under ``"opt"``, the baseline and
    mesh-buffer layout), ``err``/``sk_err`` EF memories from the baseline
    state."""
    if not isinstance(state, dict):
        return {}
    opt = state.get("opt", state)
    out = {}
    if isinstance(opt, dict):
        for key, name in (("m", "m_norm"), ("v", "v_norm"),
                          ("vhat", "vhat_norm")):
            if key in opt:
                out[name] = tree_norm(opt[key])
    ef = state.get("err", state.get("sk_err"))
    if ef is not None:
        out["ef_norm"] = tree_norm(ef)
    return out


def telemetry_probes(tel: Telemetry, *, deltas: Pytree = None,
                     update: Pytree = None, part_mask=None, state=None,
                     clip_frac=None) -> dict:
    """The selected probe scalars for one round.

    ``deltas`` leaves are (G, ...) per-client deltas, ``update`` is the
    applied server update tree, ``part_mask`` the round's EFFECTIVE
    aggregation mask (post guard_uplink), ``state`` the post-update server
    state.  Callers pass what their round has; absent inputs simply drop
    their probes.  Everything returned is an f32 scalar, so the scan
    history stacks each key to a (rounds,) array."""
    from repro.core.safl import masked_mean_tree
    out = {}
    dbar = dn = None
    if deltas is not None and (tel.delta_norm or tel.residual):
        dbar = masked_mean_tree(deltas, part_mask)
        dn = tree_norm(dbar)
        if tel.delta_norm:
            out["delta_norm"] = dn
    if tel.update_norm and update is not None:
        out["update_norm"] = tree_norm(update)
    if tel.residual and dbar is not None and update is not None:
        diff = jax.tree.map(lambda a, b: a - b.astype(jnp.float32),
                            dbar, update)
        out["residual"] = tree_norm(diff) / jnp.maximum(dn, 1e-12)
    if tel.moments and state is not None:
        out.update(state_norms(state))
    if tel.cohort and deltas is not None:
        num = jax.tree.leaves(deltas)[0].shape[0]
        out["cohort"] = effective_cohort(part_mask, num)
    if tel.clip and clip_frac is not None:
        out["clip_frac"] = clip_frac
    return {k: jnp.asarray(v, jnp.float32) for k, v in out.items()}
