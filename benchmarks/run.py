"""Benchmark harness -- one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig1_resnet_scratch   : SAFL vs baselines, training-from-scratch regime
                          (paper Fig. 1, laptop-scale LM stand-in)
  fig1_participation    : partial participation (p0.25 cohorts) + FedBuff-
                          style async staleness rows on the scanned driver
  fig1_faults           : deterministic fault injection + sketch-space
                          sentinels (repro.fed.faults/robust, DESIGN §10)
  codec_rows            : quantized payload codec (int8 / 1-bit stochastic
                          rounding + sketch-space error feedback) with the
                          MEASURED wire size next to final loss (DESIGN §13)
  fig2_finetune         : finetuning regime comparison (paper Fig. 2)
  fig3_sketch_sizes     : convergence vs sketch size b (paper Fig. 3 / Fig. 6)
  table1_comm_bits      : per-round uplink bits per algorithm (paper Table 1)
  fig5_hessian_spectrum : intrinsic dimension of the loss Hessian (Fig. 5)
  sketch_ops            : raw sk/desk operator throughput (pure-jnp + Pallas)
                          + packed-engine vs per-leaf round-trip comparison
  mesh rows (--mesh)    : per-round jitted mesh step vs the scanned mesh
                          driver (scan OUTSIDE shard_map) on the cross_silo
                          topology; needs 8 forced host devices

Run:  PYTHONPATH=src python -m benchmarks.run [--quick] [--json] [--mesh]

``--json`` additionally writes BENCH_sketch.json (name -> us_per_call, plus
``<name>.final_loss`` convergence keys for the participation/async rows) so
the perf trajectory is machine-readable across PRs.
"""

from __future__ import annotations

import functools
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptive import AdaConfig
from repro.core.baselines import (BaselineConfig, baseline_round,
                                  init_baseline_state, uplink_bits)
from repro.core.clipped import ClippedSAFLConfig, clipped_safl_round
from repro.core.intrinsic_dim import intrinsic_dimension
from repro.core.packed import (derive_round_params, desk_packed,
                               make_packing_plan, sk_packed)
from repro.core.safl import (SAFLConfig, init_safl, safl_round,
                             uplink_bits_per_round)
from repro.core.sketch import (SketchConfig, desketch_tree, sk_leaf,
                               sketch_tree, total_sketch_bits)
from repro.data import (BigramLMData, ClsDataConfig, GaussianClsData,
                        LMDataConfig)
from repro.fed import (AsyncConfig, CodecConfig, FaultConfig, SentinelConfig,
                       UniformParticipation, init_async_state,
                       init_codec_state, make_async_round)
from repro.launch.driver import make_chunk_fn
from repro.models import ModelConfig, init_params, loss_fn
from repro.obs.shards import span_stats

QUICK = "--quick" in sys.argv
JSON_OUT = "BENCH_sketch.json" if "--json" in sys.argv else None
GUARD = "--guard" in sys.argv
# --mesh: run ONLY the mesh/<algo> rows (needs >= 8 devices, e.g.
# XLA_FLAGS=--xla_force_host_platform_device_count=8) and MERGE them into an
# existing BENCH_sketch.json instead of overwriting it -- the flag lives in
# its own CI step so the forced-device flag never touches the default rows.
MESH = "--mesh" in sys.argv

_ROWS: dict[str, float] = {}


def _emit(name: str, us: float, derived: str = "", json_row: bool = True,
          final_loss: float | None = None, stats: dict | None = None) -> None:
    if json_row:
        _ROWS[name] = us
        if final_loss is not None:
            # convergence next to cost: the participation/async rows pin
            # their final training loss into the JSON trajectory too
            _ROWS[f"{name}.final_loss"] = final_loss
        if stats:
            # per-round wall-time spread over the timed runs, next to the
            # min-of-N total (informational rows: excluded from the guard,
            # since percentiles move with machine noise while min-of-N only
            # ever tightens)
            _ROWS[f"{name}.p50_us"] = stats["p50_us"]
            _ROWS[f"{name}.p95_us"] = stats["p95_us"]
    if stats:
        derived = (derived + (";" if derived else "")
                   + f"p50={stats['p50_us']:.0f}us;p95={stats['p95_us']:.0f}us")
    print(f"{name},{us:.0f},{derived}")

# the paper's three experimental regimes, at laptop scale: a small LM plays
# the role of ResNet/ViT/BERT (same optimizer/compressor mechanics).
MODEL = ModelConfig(name="bench", arch_type="dense", num_layers=2,
                    d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                    vocab_size=128)
CLIENTS, K, SEQ = 5, 2, 32          # paper: 5 clients, uniform split
ROUNDS = 10 if QUICK else 60
BPC = 10                            # batch per client


def _timer(fn, *args, reps=3):
    out = fn(*args)  # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _setup(algo: str, sketch_ratio: float, rounds: int, seed: int):
    """Common per-algorithm wiring: device sampler, round_fn with the
    PackingPlan built once outside the trace, fresh-state factory, bits."""
    data = BigramLMData(LMDataConfig(vocab_size=MODEL.vocab_size, seq_len=SEQ,
                                     num_clients=CLIENTS, seed=seed,
                                     alpha=0.03))
    sampler = data.device_sampler(BPC, K)
    params0 = init_params(MODEL, jax.random.key(seed))
    loss = lambda p, b: loss_fn(MODEL, p, b)

    if algo in ("safl", "safl_srht", "safl_gaussian", "fedopt", "clipped"):
        kind = {"safl": "countsketch", "safl_srht": "srht",
                "safl_gaussian": "gaussian", "fedopt": "none",
                "clipped": "countsketch"}[algo]
        cfg = SAFLConfig(
            sketch=SketchConfig(kind=kind, ratio=sketch_ratio, min_b=8),
            server=AdaConfig(name="amsgrad", lr=0.01),
            client_lr=0.5, local_steps=K,
            remat_local=False)     # bench model: remat buys nothing on CPU
        plan = make_packing_plan(cfg.sketch, params0)
        if algo == "clipped":      # SACFL: per-client delta clipping
            cfg = ClippedSAFLConfig(base=cfg, clip_tau=1.0)
            round_fn = functools.partial(clipped_safl_round, cfg, loss,
                                         plan=plan)
            init_state = lambda p: init_safl(cfg.base, p)
            bits = total_sketch_bits(cfg.base.sketch, params0)
        else:
            round_fn = functools.partial(safl_round, cfg, loss, plan=plan)
            init_state = lambda p: init_safl(cfg, p)
            bits = total_sketch_bits(cfg.sketch, params0)
    else:
        server = {"fedavg": AdaConfig(name="sgd", lr=1.0),
                  "topk_ef": AdaConfig(name="sgd", lr=1.0),
                  "fetchsgd": AdaConfig(name="sgd", lr=1.0),
                  "onebit_adam": AdaConfig(name="adam", lr=0.01),
                  "marina": AdaConfig(name="sgd", lr=0.5),
                  "cocktail": AdaConfig(name="sgd", lr=1.0)}[algo]
        cfg = BaselineConfig(name=algo, client_lr=0.5, local_steps=K,
                             server=server, topk_ratio=sketch_ratio,
                             sketch=SketchConfig(kind="countsketch",
                                                 ratio=sketch_ratio, min_b=8),
                             onebit_warmup=max(2, rounds // 4),
                             remat_local=False)
        plan = make_packing_plan(cfg.sketch, params0)
        round_fn = functools.partial(baseline_round, cfg, loss, plan=plan)
        init_state = lambda p: init_baseline_state(cfg, p, CLIENTS, plan=plan)
        bits = uplink_bits(cfg, params0)

    def fresh():
        p = init_params(MODEL, jax.random.key(seed))
        return p, init_state(p)

    return sampler, round_fn, fresh, bits, cfg, plan


def _train(algo: str, sketch_ratio: float = 0.05, rounds: int = ROUNDS,
           seed: int = 0, scan: bool = False, participation=None,
           async_cfg=None, faults=None, sentinel=None, codec=None):
    """Train the bench model with one algorithm; returns (final_loss,
    us_per_round, uplink_bits_per_round, stats) where ``stats`` is the
    per-round wall-time p50/p95 over the timed scan runs (``None`` on the
    host path, which is timed cold end-to-end in one pass).

    ``participation`` (a repro.fed sampling policy) and ``async_cfg`` (a
    repro.fed AsyncConfig, SAFL-family only) ride the scanned driver's
    hooks; both require ``scan=True``.  Under participation the reported
    bits are per-round for the SAMPLED cohort (per-client x cohort size).

    ``scan=False`` is the host-driven loop, timed END TO END: jit
    compilation at t=0, per-round host-side batch sampling (the legacy
    pipeline shape -- a Python loop over sequence positions, numpy out,
    cost comparable to the numpy sampler it replaces), one dispatch + one
    blocking metric fetch per round.  NOTE this is a broader protocol than
    the seed rows, which started their per-round timer AFTER batch
    generation: the host row here is the full wall-clock cost per round of
    a host-driven trainer, i.e. everything the scan driver eliminates or
    amortizes.
    ``scan=True`` runs all rounds as ONE on-device lax.scan dispatch
    (launch/driver.py) and reports STEADY STATE (compile excluded by a
    warm-up run): the driver compiles one chunk executable whose cost is
    independent of the training horizon, so the marginal per-round time is
    the meaningful number.  Both paths draw identical device-sampled
    batches under identical fold_in(key, t) round keys, so their
    trajectories agree bitwise (tests/test_driver.py pins scan == host loop
    exactly)."""
    sampler, round_fn, fresh, bits, cfg, plan = _setup(algo, sketch_ratio,
                                                       rounds, seed)
    key = jax.random.key(1000)

    if async_cfg is not None:
        assert scan and algo in ("safl", "clipped")
        base_init = fresh
        round_fn = make_async_round(cfg, (lambda p, b: loss_fn(MODEL, p, b)),
                                    async_cfg, plan)
        fresh = lambda: (base_init()[0], init_async_state(
            cfg, async_cfg, base_init()[0], plan, CLIENTS))
    if participation is not None:
        assert scan, "participation rows ride the scanned driver"
        bits = bits * participation.cohort_size
    if sentinel is not None:
        assert scan and algo in ("safl", "clipped")
        round_fn = functools.partial(round_fn, sentinel=sentinel)
    if faults is not None:
        assert scan, "fault rows ride the scanned driver's hooks"
    if codec is not None:
        assert scan and async_cfg is None and algo in ("safl", "clipped"), \
            "codec rows ride the sketched sync scan driver"
        round_fn = functools.partial(round_fn, codec=codec)
        bits = codec.payload_bits(plan.b_total)   # measured wire size/client
        if codec.error_feedback:
            base_fresh = fresh

            def fresh():
                p, s = base_fresh()
                return p, {"opt": s, "ef": init_codec_state(
                    codec, CLIENTS, plan.b_total)}

    if scan:
        chunk = make_chunk_fn(round_fn, sampler, rounds,
                              participation=participation,
                              buffer=async_cfg is not None, faults=faults)

        def run():
            p, s = fresh()
            t0 = time.perf_counter()
            _, _, _, hist = chunk(p, s, sampler.init_state(), key,
                                  jnp.asarray(0, jnp.int32))
            losses = np.asarray(hist["loss"])          # one fetch per run
            return losses, time.perf_counter() - t0
        run()                                          # compile the chunk
        losses, secs = run()                           # steady state
        times = [secs, run()[1], run()[1]]             # min-of-3: damp noise
        stats = span_stats([s / rounds for s in times])
        return (float(losses[-1]), min(times) / rounds * 1e6, bits, stats)

    step = jax.jit(round_fn, donate_argnums=(0, 1))
    p, s = fresh()
    last = None
    t0 = time.perf_counter()                           # cold, like the seed
    for t in range(rounds):
        # legacy host pipeline: Python loop over sequence positions, numpy
        # out -- same tokens as the device sampler, bit for bit
        batch = sampler.host_round_batch(t)
        p, s, m = step(p, s, batch,
                       jax.random.fold_in(key, jnp.asarray(t, jnp.int32)))
        last = float(m["loss"])                        # blocks every round
    secs = time.perf_counter() - t0
    return last, secs / rounds * 1e6, bits, None


def fig1_resnet_scratch():
    """Paper Fig. 1: training-from-scratch, SAFL vs compression baselines at
    matched compression (ratio 0.05).  Each algorithm is timed twice: the
    host-driven loop (kept for trajectory continuity; cold, end-to-end
    incl. per-round sampling) and the on-device scanned driver (steady
    state); same batches
    + round keys, so final losses agree to float32 tolerance (bitwise, in
    fact) while the _scan rows show the resident driver's marginal round
    cost."""
    for algo in ("safl", "fedopt", "fedavg", "fetchsgd", "topk_ef",
                 "onebit_adam", "cocktail", "marina"):
        final, us, bits, _ = _train(algo)
        _emit(f"fig1/{algo}", us, f"final_loss={final:.4f};uplink_bits={bits};"
              f"cold_e2e_incl_compile_and_sampling")
        final_s, us_s, _, st = _train(algo, scan=True)
        _emit(f"fig1/{algo}_scan", us_s,
              f"final_loss={final_s:.4f};steady_state;host_cold_us={us:.0f};"
              f"speedup={us / us_s:.2f}x", stats=st)


def fig1_participation():
    """Partial participation + async staleness rows (repro.fed, DESIGN §7),
    all on the scanned driver at steady state.  The _p0.25 rows sample a
    1-of-5 cohort per round (uniform without replacement, keyed off the
    round index); uplink bits are reported for the SAMPLED cohort.  The
    _async row runs the FedBuff-style staleness buffer: uniform client
    delays up to 2 rounds, arrivals discounted by (1+staleness)^-0.5.
    Final losses are pinned into BENCH_sketch.json next to the round
    times."""
    pol = UniformParticipation(CLIENTS, frac=0.25, seed=123)
    for algo in ("safl", "clipped"):
        final, us, bits, st = _train(algo, scan=True, participation=pol)
        _emit(f"fig1/{algo}_p0.25", us,
              f"final_loss={final:.4f};uplink_bits={bits};"
              f"cohort={pol.cohort_size}/{CLIENTS};steady_state",
              final_loss=final, stats=st)
    acfg = AsyncConfig(max_delay=2, delay="uniform", staleness_alpha=0.5)
    final, us, bits, st = _train("safl", scan=True, async_cfg=acfg)
    _emit("fig1/safl_async", us,
          f"final_loss={final:.4f};uplink_bits={bits};max_delay=2;"
          f"staleness_alpha=0.5;steady_state", final_loss=final, stats=st)


def fig1_faults():
    """Fault-tolerant row (repro.fed.faults/robust, DESIGN §10): determin-
    istic client faults (dropout-after-compute, NaN payloads, 1e3-scaled
    Byzantine payloads, 5% each) injected into the scanned driver, with the
    sketch-space sentinels rejecting the corrupted uplinks.  The guard chain
    (faults -> sentinels -> participation mask -> one masked mean) rides the
    same scan, so the row prices the full §10 fusion; the final loss is a
    deterministic pin -- fault draws are fold_in streams of the round index,
    so the guarded trajectory is exactly reproducible."""
    faults = FaultConfig(num_clients=CLIENTS, drop_rate=0.05, nan_rate=0.05,
                         byzantine_rate=0.05)
    sent = SentinelConfig(norm_mult=10.0)
    final, us, bits, st = _train("safl", scan=True, faults=faults,
                                 sentinel=sent)
    _emit("fig1/safl_faults", us,
          f"final_loss={final:.4f};uplink_bits={bits};"
          f"drop/nan/byz=0.05each;norm_mult=10;steady_state",
          final_loss=final, stats=st)


def codec_rows():
    """Quantized payload codec rows (repro.fed.codec, DESIGN §13): the
    packed sketch uplink is stochastically rounded to int8 / 1-bit with
    sketch-space error feedback, and the reported uplink bits are the
    MEASURED encoded size per client (mantissa bits + the 32-bit per-row
    scale) -- real bits on the wire, priced NEXT TO the final loss so the
    accuracy/bandwidth trade is one row.  The ratio vs the float32 payload
    is 8/32 + 1/b_total (int8) and 1/32 + 1/b_total (1-bit): the scale
    word is real overhead and is billed, not hidden.  Guarded _scan rows:
    steady state under the 2x time budget, exact final-loss pins."""
    params0 = init_params(MODEL, jax.random.key(0))
    plan = make_packing_plan(SketchConfig(kind="countsketch", ratio=0.05,
                                          min_b=8), params0)
    f32_bits = 32 * plan.b_total
    for tag, qbits in (("int8", 8), ("1bit", 1)):
        codec = CodecConfig(bits=qbits)
        final, us, wire, st = _train("safl", scan=True, codec=codec)
        _emit(f"codec/safl_{tag}_scan", us,
              f"final_loss={final:.4f};measured_bits_per_client={wire};"
              f"float32_bits={f32_bits};ratio={wire / f32_bits:.4f};"
              f"error_feedback=on;steady_state",
              final_loss=final, stats=st)


def fig2_finetune():
    """Paper Fig. 2: finetuning regime comparison."""
    for algo in ("safl", "onebit_adam", "fetchsgd"):
        final, us, bits, _ = _train(algo, seed=7, rounds=(5 if QUICK else 30))
        _emit(f"fig2/{algo}", us, f"final_loss={final:.4f}")


def fig3_sketch_sizes():
    """Paper Fig. 3/6: convergence vs sketch size (training error monotone
    in b; tiny b still converges)."""
    for ratio in (0.01, 0.05, 0.2, 1.0):
        final, us, bits, _ = _train("safl", sketch_ratio=ratio)
        _emit(f"fig3/ratio_{ratio}", us, f"final_loss={final:.4f};bits={bits}")


def table1_comm_bits():
    """Paper Table 1: per-round communication bits per algorithm."""
    params = init_params(MODEL, jax.random.key(0))
    d = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
    rows = {
        "fedopt": d * 32,
        "safl(b=.01d)": total_sketch_bits(
            SketchConfig(kind="countsketch", ratio=0.01, min_b=8), params),
    }
    for name in ("fetchsgd", "topk_ef", "onebit_adam", "marina", "cocktail"):
        cfg = BaselineConfig(name=name, topk_ratio=0.01,
                             sketch=SketchConfig(kind="countsketch",
                                                 ratio=0.01, min_b=8))
        rows[name] = uplink_bits(cfg, params)
    for k, v in rows.items():
        _emit(f"table1/{k}", 0.0, f"uplink_bits={v};ratio_vs_dense={v / (d * 32):.4f}")


def fig5_hessian_spectrum():
    """Paper Fig. 5 / Assumption 4: intrinsic dimension << ambient dim."""
    data = BigramLMData(LMDataConfig(vocab_size=MODEL.vocab_size, seq_len=SEQ,
                                     num_clients=1))
    params = init_params(MODEL, jax.random.key(0))
    batch = data.client_batch(0, 16, seed=0)
    t0 = time.perf_counter()
    out = intrinsic_dimension(lambda p, b: loss_fn(MODEL, p, b), params,
                              batch, num_iters=(8 if QUICK else 20),
                              num_probes=(1 if QUICK else 2))
    us = (time.perf_counter() - t0) * 1e6
    _emit("fig5/intrinsic_dim", us,
          f"I={out['intrinsic_dim']:.1f};ambient_d={out['ambient_dim']};"
          f"ratio={out['intrinsic_dim'] / out['ambient_dim']:.2e}")


def sketch_ops():
    """Raw operator cost: sk over a 1M-dim vector, jnp vs Pallas route."""
    n, b = (1 << 18, 1 << 10) if QUICK else (1 << 20, 1 << 12)
    v = jax.random.normal(jax.random.key(0), (n,))
    key = jax.random.key(1)
    for kind in ("countsketch", "srht"):
        cfg = SketchConfig(kind=kind, ratio=b / n, min_b=b)
        f = jax.jit(lambda vv: sk_leaf(cfg, key, vv))
        us = _timer(f, v)
        _emit(f"sketch_ops/{kind}_jnp", us, f"n={n};b={b}")
    from repro.kernels import ops
    h = jax.random.randint(jax.random.key(2), (n,), 0, b)
    us = _timer(lambda: ops.countsketch(v, h, b))
    # off-TPU the kernel runs under interpret=True: the number is Python
    # interpreter overhead, not kernel cost.  Label it _interp and keep it
    # OUT of the JSON trajectory so it cannot be read as a perf regression.
    # (ops._interpret is the kernels' own routing predicate -- one source
    # of truth for "did this actually compile".)
    interp = ops._interpret()
    _emit("sketch_ops/countsketch_pallas" + ("_interp" if interp else ""),
          us, f"n={n};b={b}" + (";interpreter-overhead,excluded-from-json"
                                if interp else ""),
          json_row=not interp)
    packed_vs_perleaf()


def packed_vs_perleaf():
    """Fused packed-engine round trip vs the seed per-leaf loop on the bench
    model (per-tensor countsketch, same ratio/payload).  The packed path
    derives hashes/signs ONCE per round (shared by sk and desk) and
    compresses the whole tree in one fused pass with the scatter-free
    balanced hash family; the per-leaf loop re-derives per leaf on both
    sides and scatter-adds leaf by leaf (the pre-packed hot path).  A
    same-family packed row isolates the pure fusion/derive-once win."""
    params = init_params(MODEL, jax.random.key(0))
    key = jax.random.key(3)
    # seed reference hot path: per-leaf loop, independent-hash countsketch
    cfg_ref = SketchConfig(kind="countsketch", ratio=0.05, min_b=8,
                           cs_hash="independent")
    # production packed path: fused, balanced hash family (default)
    cfg_pk = SketchConfig(kind="countsketch", ratio=0.05, min_b=8)

    @jax.jit
    def perleaf_rt(t):
        return desketch_tree(cfg_ref, key, sketch_tree(cfg_ref, key, t), t)

    def packed_fn(cfg):
        plan = make_packing_plan(cfg, params)

        @jax.jit
        def rt(t):
            rp = derive_round_params(plan, key)
            return desk_packed(plan, rp, sk_packed(plan, rp, t))
        return plan, rt

    plan, packed_rt = packed_fn(cfg_pk)
    _, packed_ind_rt = packed_fn(cfg_ref)

    reps = 20
    us_perleaf = _timer(perleaf_rt, params, reps=reps)
    us_packed = _timer(packed_rt, params, reps=reps)
    us_packed_ind = _timer(packed_ind_rt, params, reps=reps)
    _emit("sketch_ops/packed_vs_perleaf", us_packed,
          f"perleaf_us={us_perleaf:.0f};speedup={us_perleaf / us_packed:.2f}x;"
          f"d={plan.d_total};b_total={plan.b_total};leaves={len(plan.ops)}")
    _emit("sketch_ops/packed_vs_perleaf_samefamily", us_packed_ind,
          f"perleaf_us={us_perleaf:.0f};"
          f"speedup={us_perleaf / us_packed_ind:.2f}x")


def mesh_rows():
    """mesh/<algo> (host-driven per-round jitted mesh step) vs
    mesh/<algo>_scan (R rounds as ONE lax.scan OUTSIDE the shard_map round,
    donated (params, opt, data_state, key) carries, steady state) on the
    cross_silo production topology: a (2, 2, 2) pod/data/model mesh, one FL
    client per pod, FSDP weights, mb data-sharded.  Final losses of the two
    rows are asserted bitwise-equal (ISSUE 4 acceptance) and pinned into the
    JSON as <name>.final_loss next to the round times; --guard covers the
    _scan rows.  cross_silo rather than cross_device because the latter's
    partial-manual shard_map needs the jax>=0.6 stack (DESIGN §8)."""
    if jax.device_count() < 8:
        if GUARD:
            # never let the guarded CI step go green without its rows: if
            # the forced-device flag stopped taking effect, fail loudly
            sys.exit("# --mesh --guard needs >= 8 devices "
                     f"(have {jax.device_count()}); set XLA_FLAGS="
                     "--xla_force_host_platform_device_count=8")
        print("# mesh rows skipped: need >= 8 devices (run with XLA_FLAGS="
              "--xla_force_host_platform_device_count=8)")
        return
    from repro.launch.mesh import _mesh
    from repro.launch.train import (init_mesh_async_state,
                                    make_safl_train_step, mesh_sampler,
                                    run_mesh_host_loop, make_safl_scan_fn)
    from repro.models.sharding import use_mesh
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    topo = "cross_silo"
    rounds = 6 if QUICK else 20
    data = BigramLMData(LMDataConfig(vocab_size=MODEL.vocab_size, seq_len=SEQ,
                                     num_clients=2, alpha=0.03))
    key = jax.random.key(1000)
    with use_mesh(mesh):
        # batch_per_client 8, not BPC=10: mb = 8/K = 4 divides the 2-way
        # data axis, so per-round and scanned programs partition identically
        # (a padded mb would reorder the loss/psum reductions and break the
        # bitwise pin)
        smp = mesh_sampler(mesh, data.device_sampler(8, K), topo)
        # key_data(key) aliases key's buffer and the scanned chunks donate
        # it: hand each run a fresh device copy of the host value
        kd_host = np.asarray(jax.random.key_data(key))

        def scan_row(chunk, fresh):
            """Steady-state timing of one scanned chunk fn: compile via a
            warm-up run, min-of-3 to damp noise, ONE metric fetch per run.
            The single timing harness for every scanned mesh row; also
            returns the per-round p50/p95 over the timed runs."""
            def run():
                p, s = fresh()
                t0 = time.perf_counter()
                _, _, _, _, hist = chunk(p, s, smp.init_state(),
                                         jnp.asarray(kd_host),
                                         jnp.asarray(0, jnp.int32))
                losses = np.asarray(hist["loss"])   # one fetch per run
                return losses, time.perf_counter() - t0
            run()                                   # compile
            losses, secs = run()
            times = [secs, run()[1], run()[1]]
            st = span_stats([s / rounds for s in times])
            return float(losses[-1]), min(times) / rounds * 1e6, st

        for algo, kind in (("safl", "countsketch"), ("fedopt", "none")):
            cfg = SAFLConfig(
                sketch=SketchConfig(kind=kind, ratio=0.05, min_b=8),
                server=AdaConfig(name="amsgrad", lr=0.01),
                client_lr=0.5, local_steps=K, remat_local=False)
            step, _ = make_safl_train_step(MODEL, cfg, mesh, topo)

            def fresh(cfg=cfg):
                p = init_params(MODEL, jax.random.key(0))
                return p, init_safl(cfg, p)

            # host-driven per-round reference: cold end to end (compile at
            # t=0, one dispatch + one blocking loss fetch per round)
            t0 = time.perf_counter()
            _, _, h_host = run_mesh_host_loop(step, smp, *fresh(),
                                              rounds=rounds, key=key)
            us_host = (time.perf_counter() - t0) / rounds * 1e6
            final_host = float(h_host["loss"][-1])

            # scanned: one chunk executable, steady state
            chunk, _ = make_safl_scan_fn(MODEL, cfg, mesh, topo, sampler=smp,
                                         num_rounds=rounds)
            final_scan, us_scan, st = scan_row(chunk, fresh)

            assert final_scan == final_host, (
                f"mesh/{algo}: scanned final loss {final_scan!r} != "
                f"per-round {final_host!r} (bitwise parity broken)")
            _emit(f"mesh/{algo}", us_host,
                  f"final_loss={final_host:.4f};host_per_round;cold_e2e",
                  final_loss=final_host)
            _emit(f"mesh/{algo}_scan", us_scan,
                  f"final_loss={final_scan:.4f};steady_state;parity=bitwise;"
                  f"host_cold_us={us_host:.0f};"
                  f"speedup={us_host / us_scan:.2f}x",
                  final_loss=final_scan, stats=st)

        # --- federated realism on the mesh (ISSUE 5): partial cohorts and
        # FedBuff-style async staleness riding the SAME scanned mesh driver,
        # steady state, final losses pinned into the JSON trajectory ---
        from repro.launch.train import num_clients_of
        cfg = SAFLConfig(
            sketch=SketchConfig(kind="countsketch", ratio=0.05, min_b=8),
            server=AdaConfig(name="amsgrad", lr=0.01),
            client_lr=0.5, local_steps=K, remat_local=False)
        G = num_clients_of(mesh, topo)

        def fresh_p():
            p = init_params(MODEL, jax.random.key(0))
            return p, init_safl(cfg, p)

        pol = UniformParticipation(G, frac=0.25, seed=123)
        chunk_p, _ = make_safl_scan_fn(MODEL, cfg, mesh, topo, sampler=smp,
                                       num_rounds=rounds, participation=pol)
        final_p, us_p, st_p = scan_row(chunk_p, fresh_p)
        _emit("mesh/safl_p0.25", us_p,
              f"final_loss={final_p:.4f};cohort={pol.cohort_size}/{G};"
              f"steady_state", final_loss=final_p, stats=st_p)

        acfg = AsyncConfig(max_delay=2, delay="uniform", staleness_alpha=0.5)
        chunk_a, _ = make_safl_scan_fn(MODEL, cfg, mesh, topo, sampler=smp,
                                       num_rounds=rounds, buffer=acfg)

        def fresh_a():
            p = init_params(MODEL, jax.random.key(0))
            return p, init_mesh_async_state(MODEL, cfg, acfg, mesh, p, topo)

        final_a, us_a, st_a = scan_row(chunk_a, fresh_a)
        _emit("mesh/safl_async", us_a,
              f"final_loss={final_a:.4f};max_delay=2;staleness_alpha=0.5;"
              f"steady_state", final_loss=final_a, stats=st_a)

        # fault injection + sketch-space sentinels on the scanned mesh
        # driver (DESIGN §10): per-client faults drawn on every device from
        # the same fold_in stream, sentinel validity agreed via one psum of
        # two (G,) stats arrays, payload still aggregated by the ONE
        # masked psum-mean.  Deterministic -- the final loss is a pin.
        fts = FaultConfig(num_clients=G, drop_rate=0.05, nan_rate=0.05,
                          byzantine_rate=0.05)
        chunk_f, _ = make_safl_scan_fn(MODEL, cfg, mesh, topo, sampler=smp,
                                       num_rounds=rounds, faults=fts,
                                       sentinel=SentinelConfig(norm_mult=10.0))
        final_f, us_f, st_f = scan_row(chunk_f, fresh_p)
        _emit("mesh/safl_faults", us_f,
              f"final_loss={final_f:.4f};drop/nan/byz=0.05each;norm_mult=10;"
              f"steady_state", final_loss=final_f, stats=st_f)


def stream_rows():
    """Streamed client-microbatch aggregation at simulated-population scale
    (DESIGN §12, ISSUE 9): a 330-parameter linear classifier on the
    device-side Gaussian-mixture sampler, aggregated with
    ``microbatch=1024`` so the round never materializes the (G, b_total)
    payload or the (G, d) delta stack -- peak aggregation memory is
    O(microbatch x b_total) at every G.

    Rows:
      stream/safl_G100000_scan : guarded steady-state row (the ``_scan``
        suffix puts it under the 2x time budget and the exact
        ``.final_loss`` pin) -- 100k simulated clients per round on CPU.
      stream/scaling_G{n}      : the scaling curve (1k/10k/100k, plus 1M
        when not --quick).  Informational: round time scales ~linearly in
        G while memory stays flat, so these rows move with G by design and
        stay OUT of the guard (no _scan/_async/_faults suffix).
    """
    F, C = 32, 10
    sk = SketchConfig(kind="countsketch", ratio=0.25, min_b=64)
    cfg = SAFLConfig(sketch=sk, server=AdaConfig(name="amsgrad", lr=0.05),
                     client_lr=0.1, local_steps=1)
    params0 = {"W": jnp.zeros((F, C)), "b": jnp.zeros((C,))}
    plan = make_packing_plan(sk, params0)
    bits_client = uplink_bits_per_round(cfg, params0)
    MB = 1024

    def cls_loss(p, b):
        logits = b["x"] @ p["W"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(
            logp, b["y"][..., None], axis=-1))

    round_fn = functools.partial(safl_round, cfg, cls_loss, plan=plan)

    def timed_rounds(G, rounds):
        data = GaussianClsData(ClsDataConfig(
            num_features=F, num_classes=C, num_clients=G,
            dirichlet_alpha=0.0, seed=0))
        sampler = data.device_sampler(2, 1)
        chunk = make_chunk_fn(round_fn, sampler, rounds, microbatch=MB)
        key = jax.random.key(1000)

        def run():
            p = jax.tree.map(jnp.zeros_like, params0)
            s = init_safl(cfg, p)
            t0 = time.perf_counter()
            _, _, _, hist = chunk(p, s, sampler.init_state(), key,
                                  jnp.asarray(0, jnp.int32))
            losses = np.asarray(hist["loss"])
            return losses, time.perf_counter() - t0
        run()                                      # compile
        losses, s1 = run()
        _, s2 = run()
        return losses, min(s1, s2) / rounds * 1e6

    # guarded row: 100k clients per round, fixed 2-round horizon so the
    # final-loss pin is identical in quick and full runs
    G0 = 100_000
    losses, us = timed_rounds(G0, 2)
    _emit("stream/safl_G100000_scan", us,
          f"final_loss={losses[-1]:.4f};microbatch={MB};"
          f"uplink_bits={bits_client * G0};"
          f"payload_rows_resident={MB}_of_{G0}",
          final_loss=float(losses[-1]))

    # scaling curve: round time vs simulated population, memory flat
    sizes = [1_000, 10_000, 100_000] + ([] if QUICK else [1_000_000])
    for G in sizes:
        losses, us = timed_rounds(G, 2)
        _emit(f"stream/scaling_G{G}", us,
              f"final_loss={losses[-1]:.4f};uplink_bits={bits_client * G};"
              f"bits_per_client={bits_client};microbatch={MB}")


def _guarded_row(name: str) -> bool:
    """Steady-state scanned rows only: fig1/*_scan and mesh/*_scan plus the
    participation (_p{frac}), async-buffer (_async) and fault-injection
    (_faults) rows, which also run as one on-device scan with compilation
    excluded.  The *.final_loss convergence keys are pins, not times --
    excluded from the 2x time budget here; ``_perf_guard`` separately holds
    the guarded rows' ``.final_loss`` keys to EXACT equality."""
    if name.endswith(".final_loss"):
        return False
    if name.endswith(".p50_us") or name.endswith(".p95_us"):
        # percentile companions are informational: they track machine noise
        # (and "_p0" below would otherwise catch e.g. fig1/safl_p0.25.p50_us)
        return False
    return (name.endswith("_scan") or name.endswith("_async")
            or name.endswith("_faults") or "_p0" in name)


def _perf_guard(prev: dict[str, float]) -> list[str]:
    """CI guard: fail when a guarded steady-state round time regresses >2x
    against the committed BENCH_sketch.json baseline (comparable across
    machines because compilation is excluded), OR when a scanned row's
    pinned final loss drifts AT ALL.  The ``.final_loss`` keys of every
    guarded row (_scan, _p{frac}, _async) are deterministic convergence
    pins (device-sampled batches, fold_in round keys, no wall-clock in
    the trajectory), so anything other than exact equality is a silent
    numeric regression -- a >2x time budget must not paper over one.
    NOTE the pins are quick-mode values on a pinned jax stack (ci.yml):
    regenerate with ``--quick --json`` when deliberately changing
    numerics."""
    fails = []
    for name, us in sorted(_ROWS.items()):
        if name.endswith(".final_loss"):
            # every guarded steady-state scan row's loss is deterministic
            # (device sampling + fold_in streams, no wall clock), so its
            # pin is exact: _scan, _p{frac} and _async rows alike
            if not _guarded_row(name[:-len(".final_loss")]):
                continue
            old = prev.get(name)
            if old is not None and us != old:
                fails.append(f"{name}: {us!r} != committed {old!r} "
                             f"(exact-equality convergence pin)")
            continue
        if not _guarded_row(name):
            continue
        old = prev.get(name)
        if old and us > 2.0 * old:
            fails.append(f"{name}: {us:.0f}us vs committed {old:.0f}us "
                         f"({us / old:.2f}x > 2x budget)")
    return fails


def main() -> None:
    prev: dict[str, float] = {}
    if GUARD or JSON_OUT:
        try:
            with open("BENCH_sketch.json") as f:
                prev = json.load(f)
        except (OSError, json.JSONDecodeError):
            if GUARD:
                print("# --guard: no committed BENCH_sketch.json baseline; "
                      "guard is a no-op")
    print("name,us_per_call,derived")
    if MESH:
        mesh_rows()
    else:
        table1_comm_bits()
        fig3_sketch_sizes()
        fig1_resnet_scratch()
        fig1_participation()
        fig1_faults()
        codec_rows()
        fig2_finetune()
        fig5_hessian_spectrum()
        sketch_ops()
        stream_rows()
    if JSON_OUT:
        # the two modes own disjoint row namespaces and each preserves the
        # other's committed baseline: --mesh merges its mesh/* rows in, the
        # default run refreshes everything EXCEPT mesh/* (so a default run
        # cannot delete the mesh baseline the mesh --guard step compares
        # against)
        if MESH:
            out = {**prev, **_ROWS}
        else:
            out = {**{k: v for k, v in prev.items()
                      if k.startswith("mesh/")}, **_ROWS}
        with open(JSON_OUT, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"# wrote {JSON_OUT} ({len(_ROWS)} rows)")
    if GUARD:
        fails = _perf_guard(prev)
        if fails:
            print("# PERF GUARD FAILED")
            for line in fails:
                print("#   " + line)
            sys.exit(1)
        print("# perf guard ok")


if __name__ == "__main__":
    main()
