"""Per-row delta table between two BENCH_sketch.json perf trajectories.

    python -m benchmarks.delta OLD.json NEW.json [--fail-on-missing]

Prints one markdown-ish row per key present in either file: old value, new
value, and the delta (a ratio + percent change for ``us_per_call`` rows, an
exact-drift flag for ``*.final_loss`` convergence pins -- those are bitwise
pins, so any drift is called out even when numerically tiny).  Rows missing
from either side are reported as NEW / MISSING, never crashed on, and
non-numeric values degrade to a string comparison.  CI runs this after the
bench job against (a) the committed baseline and (b) the previous run's
uploaded artifact, so a PR's perf movement is readable from the job log
without downloading anything.

Purely informational by default (the enforcement lives in
``benchmarks.run --guard``); ``--fail-on-missing`` exits non-zero when NEW
dropped rows OLD had, which would silently shrink guard coverage.
"""

from __future__ import annotations

import json
import sys


def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _guarded(name: str) -> bool:
    """Mirror of ``benchmarks.run._guarded_row`` (kept dependency-free: this
    module must load without jax).  Guarded rows sit under the 2x time
    budget / exact final-loss pin; everything else -- including the
    ``stream/scaling_G*`` curve, which moves with the simulated population
    by design -- is informational."""
    base = name[:-len(".final_loss")] if name.endswith(".final_loss") else name
    if base.endswith(".p50_us") or base.endswith(".p95_us"):
        return False
    return (base.endswith("_scan") or base.endswith("_async")
            or base.endswith("_faults") or "_p0" in base)


def _fmt_time(us) -> str:
    return f"{us:,.0f}us" if _num(us) else str(us)


def delta_rows(old: dict, new: dict) -> list[tuple[str, str, str, str]]:
    rows = []
    for name in sorted(set(old) | set(new)):
        o, n = old.get(name), new.get(name)
        if o is None:
            rows.append((name, "-", _fmt_val(name, n), "NEW"))
        elif n is None:
            rows.append((name, _fmt_val(name, o), "-", "MISSING"))
        elif not (_num(o) and _num(n)):
            # malformed / non-numeric entries: compare as strings, never crash
            rows.append((name, str(o), str(n), "=" if o == n else "CHANGED"))
        elif name.endswith(".final_loss"):
            drift = "exact" if n == o else f"DRIFT {n - o:+.3e}"
            if not _guarded(name):
                drift += " (info)"
            rows.append((name, f"{o:.6f}", f"{n:.6f}", drift))
        elif not o:
            rows.append((name, _fmt_time(o), _fmt_time(n),
                         "=" if n == o else "NEW-NONZERO"))
        else:
            d = f"{n / o:.2f}x ({(n - o) / o * 100:+.1f}%)"
            if not _guarded(name):
                d += " (info)"
            rows.append((name, _fmt_time(o), _fmt_time(n), d))
    return rows


def _fmt_val(name: str, v) -> str:
    if name.endswith(".final_loss") and _num(v):
        return f"{v:.6f}"
    return _fmt_time(v)


def main(argv: list[str]) -> int:
    fail_on_missing = "--fail-on-missing" in argv
    paths = [a for a in argv if not a.startswith("--")]
    if len(paths) != 2:
        print(__doc__)
        return 2
    with open(paths[0]) as f:
        old = json.load(f)
    with open(paths[1]) as f:
        new = json.load(f)
    rows = delta_rows(old, new)
    w = max(len(r[0]) for r in rows) if rows else 4
    print(f"| {'row':<{w}} | {'old':>14} | {'new':>14} | delta |")
    print(f"|{'-' * (w + 2)}|{'-' * 16}|{'-' * 16}|-------|")
    missing = 0
    for name, o, n, d in rows:
        print(f"| {name:<{w}} | {o:>14} | {n:>14} | {d} |")
        missing += d == "MISSING"
    if missing and fail_on_missing:
        print(f"# {missing} row(s) dropped from the trajectory")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
