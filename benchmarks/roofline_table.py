"""Render the §Roofline markdown table from reports/*.jsonl dry-run output.

    PYTHONPATH=src python -m benchmarks.roofline_table reports/dryrun.jsonl
"""

from __future__ import annotations

import json
import sys


def fmt(x: float) -> str:
    return f"{x:.3e}"


def main(paths):
    rows = []
    seen = set()
    for path in paths:
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                key = (r["arch"], r["shape"], r["mesh"],
                       r.get("note", "").split(" ")[0])
                if key in seen:
                    continue
                seen.add(key)
                rows.append(r)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print("| arch | shape | mesh | compute_s | memory_s | collective_s |"
          " dominant | MODEL_FLOPS | useful | note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        note = r.get("note", "")
        topo = "silo" if "cross_silo" in note else "device"
        step = "fedopt" if "step=fedopt" in note else "safl"
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
              f" {fmt(r['compute_s'])} | {fmt(r['memory_s'])} |"
              f" {fmt(r['collective_s'])} | **{r['dominant']}** |"
              f" {fmt(r['model_flops'])} | {r['useful_flops_ratio']:.3f} |"
              f" {step}/{topo} |")


if __name__ == "__main__":
    main(sys.argv[1:] or ["reports/dryrun.jsonl"])
