import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, re, collections
import repro.launch.roofline as RL
from repro.launch import dryrun as DR
import repro.launch.hlo_costs as H

orig = RL.analyze
cap = {}
def f(compiled, **kw):
    cap["t"] = compiled.as_text()
    return orig(compiled, **kw)
RL.analyze = f
DR.lower_one(sys.argv[1], sys.argv[2], multi_pod=False, step_kind="safl",
             verbose=False, serve_layout=os.environ.get("SERVE_LAYOUT","default"))
agg = collections.Counter()
for ln in cap["t"].splitlines():
    m = H._OP_LINE.match(ln)
    if not m: continue
    rhs = m.group(2)
    if " all-gather(" in rhs or " all-gather-start(" in rhs:
        idx = rhs.index(" all-gather")
        b = H._all_shapes_bytes(rhs[:idx])
        om = re.search(r'op_name="([^"]*)"', rhs)
        frame = re.search(r'stack_frame_id=(\d+)', rhs)
        agg[(rhs[:60], om.group(1)[:90] if om else "?")] += b
for (shape, tag), b in agg.most_common(8):
    print(f"{b/1e9:8.3f} GB  {shape}\n           {tag}")
