import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, re, collections
import jax
from repro.launch import dryrun as DR
from repro.launch import hlo_costs as HC

arch, shape = sys.argv[1], sys.argv[2]
step = sys.argv[3] if len(sys.argv) > 3 else "safl"

# monkeypatch analyze to capture hlo text
import repro.launch.roofline as RL
orig = RL.analyze
captured = {}
def cap(compiled, **kw):
    captured["hlo"] = compiled.as_text()
    return orig(compiled, **kw)
RL.analyze = cap
import os as _os
kw = {}
if _os.environ.get("SERVE_LAYOUT"): kw["serve_layout"] = _os.environ["SERVE_LAYOUT"]
if _os.environ.get("TOPOLOGY"): kw["topology"] = _os.environ["TOPOLOGY"]
rep, _ = DR.lower_one(arch, shape, multi_pod=False, step_kind=step, verbose=False, **kw)
print(f"== {arch} x {shape} [{step}]  coll={rep.collective_s:.3f}s comp={rep.compute_s:.3f}s mem={rep.memory_s:.3f}s")

text = captured["hlo"]
# reuse the computation-multiplier machinery
import repro.launch.hlo_costs as H
comps = {}
cur = None
for line in text.splitlines():
    if (line and not line.startswith(" ") and "->" in line and line.rstrip().endswith("{")
            and (line.startswith("%") or line.startswith("ENTRY"))):
        tok = line.split()[1] if line.startswith("ENTRY") else line.split()[0]
        cur = tok.lstrip("%"); comps[cur] = []
        continue
    if line.startswith("}"): cur = None; continue
    if cur is not None: comps[cur].append(line)
children = collections.defaultdict(list)
fusion = set()
for name, lines in comps.items():
    for ln in lines:
        m = H._OP_LINE.match(ln)
        if not m: continue
        rhs = m.group(2)
        if " while(" in rhs:
            trips = 1.0
            tm = H._TRIP.search(rhs)
            if tm: trips = float(tm.group(1))
            bm = re.search(r"body=%?([\w\.\-]+)", rhs)
            cm = re.search(r"condition=%?([\w\.\-]+)", rhs)
            if bm: children[name].append((bm.group(1), trips))
            if cm: children[name].append((cm.group(1), trips))
        elif " fusion(" in rhs:
            fm = re.search(r"calls=%?([\w\.\-]+)", rhs)
            if fm: fusion.add(fm.group(1))
        elif " call(" in rhs:
            fm = re.search(r"to_apply=%?([\w\.\-]+)", rhs)
            if fm: children[name].append((fm.group(1), 1.0))
ref = {c for l in children.values() for c,_ in l} | fusion
mult = collections.defaultdict(float)
def walk(c, m):
    mult[c] += m
    for ch, k in children.get(c, []): walk(ch, m*k)
for e in [c for c in comps if c not in ref]: walk(e, 1.0)

agg = collections.Counter()
for name, lines in comps.items():
    w = mult.get(name, 0.0)
    if w == 0: continue
    for ln in lines:
        m = H._OP_LINE.match(ln)
        if not m: continue
        rhs = m.group(2)
        for kind in H.COLL_KINDS:
            hit = None
            for form in (f" {kind}(", f" {kind}-start("):
                if form in rhs: hit = form; break
            if not hit: continue
            b = H._all_shapes_bytes(rhs[:rhs.index(hit)])
            om = re.search(r'op_name="([^"]*)"', rhs)
            tag = om.group(1) if om else "?"
            # collapse tag to a compact source label
            tag = re.sub(r"/closed_call", "", tag)
            tag = re.sub(r"\.[0-9]+", "", tag)
            parts = [p for p in tag.split("/") if p not in ("jit(step)","while","body","checkpoint")][:6]
            agg["/".join(parts) + f" [{kind}]"] += int(w*b)
total = sum(agg.values())
print(f"total collective bytes/device: {total/1e9:.2f} GB")
for tag, b in agg.most_common(18):
    print(f"  {b/1e9:9.3f} GB  {tag}")
